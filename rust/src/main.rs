//! `lorif` — CLI for the LoRIF training-data-attribution system.
//!
//! Subcommands:
//!   info            print config, tier dims, storage estimates
//!   store inspect   print a store's manifest/layout/codec/byte report
//!   store recode    migrate a store between codecs/layouts (streaming)
//!   metrics dump    print the telemetry registry (Prometheus text)
//!   slowlog         fetch a running server's slow-query log
//!   gen-corpus      generate + persist the synthetic topic corpus [xla]
//!   train           train the base model (cached checkpoint)      [xla]
//!   build-index     stage 1 (gradient stores) + stage 2 (curvature) [xla]
//!   query           offline attribution for the held-out query set  [xla]
//!   serve           TCP attribution service with dynamic batching   [xla]
//!   eval-lds        LDS for a method (subset retraining, cached)    [xla]
//!   eval-tailpatch  tail-patch score for a method                   [xla]
//!   judge           programmatic top-1 relevance judge              [xla]
//!
//! Subcommands marked [xla] drive the PJRT runtime and need the `xla`
//! cargo feature plus `make artifacts`; the default pure-CPU build
//! reports a clear error for them.  The `store` subcommands are pure
//! CPU: any store on disk can be inspected or migrated without
//! artifacts or re-extraction.
//!
//! Common flags: --tier small|medium|large --f N --c N --r N
//!   --n-train N --n-query N --seed S --work-dir D --artifacts-dir D
//!   --shards S --score-threads T --sink full|topk
//!   --prune on|off|slack=x|recall=x --prefetch-depth N --summary-chunk N
//!   --cluster K --chunk-cache-mb N --codec bf16|int8|int4
//!   --quant-score on|off|auto --trace-out PATH
//!   --method lorif|logra|graddot|trackstar|repsim|ekfac
//! Serve flags: --addr A --max-batch N --window-ms N --topk K
//!   --score-workers N --queue-cap N --io-timeout-ms N --slowlog K
//!   --node --node-shards LIST     serve a manifest-shard subset (node mode)
//!   --coordinator --nodes addr=shards[/replica],... [--total-shards N]
//!                 [--vocab N --seq-len N]   scatter-gather front end (pure CPU)
//! Coordinator fleet flags: --probe-interval-ms N --probe-timeout-ms N
//!   --probe-failures N --scrape-interval-ms N --event-log PATH
//! Store recode flags: --out BASE --codec bf16|int8|int4 [--shards S]
//!   [--summary-chunk G] [--chunk-size N] [--cluster K]

use lorif::cli::Args;
use lorif::config::Config;
use lorif::store::Codec;

#[cfg(feature = "xla")]
use lorif::app::{self, Method};
#[cfg(feature = "xla")]
use lorif::eval::{LdsActuals, LdsProtocol, TailPatchProtocol};
#[cfg(feature = "xla")]
use lorif::index::{Pipeline, Stage1Options};
#[cfg(feature = "xla")]
use lorif::query::{QueryEngine, ServerConfig};
#[cfg(feature = "xla")]
use lorif::runtime::GradExtractor;

const XLA_SUBCOMMANDS: &[&str] = &[
    "gen-corpus",
    "train",
    "build-index",
    "query",
    "serve",
    "eval-lds",
    "eval-tailpatch",
    "judge",
];

fn main() {
    lorif::util::logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    if args.subcommand.is_empty() || args.has("help") {
        print_help();
        return Ok(());
    }
    let mut cfg = Config::default();
    args.apply_to_config(&mut cfg)?;
    if let Some(path) = &cfg.trace_out {
        lorif::telemetry::trace::init(path)?;
        log::info!("trace spans -> {} (Chrome trace-event JSON)", path.display());
    }

    match args.subcommand.as_str() {
        "info" => info(&cfg),
        "store" => store_cmd(&args),
        "metrics" => metrics_cmd(&args),
        "slowlog" => slowlog_cmd(&args),
        // the scatter-gather coordinator never touches the model — it
        // forwards validated token rows and merges node heaps — so it
        // dispatches BEFORE the xla gate and works in pure-CPU builds
        "serve" if args.has("coordinator") => serve_coordinator(&args),
        #[cfg(feature = "xla")]
        "gen-corpus" => {
            let p = Pipeline::new(cfg)?;
            let (train, queries) = p.corpus()?;
            println!(
                "corpus: {} train / {} query examples, {} topics, seq_len {}",
                train.len(),
                queries.len(),
                p.cfg.n_topics,
                train.seq_len
            );
            Ok(())
        }
        #[cfg(feature = "xla")]
        "train" => {
            let p = Pipeline::new(cfg)?;
            let (train, _) = p.corpus()?;
            let params = p.base_params(&train)?;
            println!("trained base model ({} params)", params.len());
            Ok(())
        }
        #[cfg(feature = "xla")]
        "build-index" => build_index(cfg, &args),
        #[cfg(feature = "xla")]
        "query" => query(cfg, &args),
        #[cfg(feature = "xla")]
        "serve" => serve(cfg, &args),
        #[cfg(feature = "xla")]
        "eval-lds" => eval_lds(cfg, &args),
        #[cfg(feature = "xla")]
        "eval-tailpatch" => eval_tailpatch(cfg, &args),
        #[cfg(feature = "xla")]
        "judge" => judge(cfg, &args),
        other if XLA_SUBCOMMANDS.contains(&other) => anyhow::bail!(
            "subcommand '{other}' needs the PJRT runtime: rebuild with \
             `cargo build --release --features xla` (see rust/README.md)"
        ),
        other => anyhow::bail!("unknown subcommand '{other}' (--help for usage)"),
    }
}

/// `lorif store <inspect|recode>` — pure-CPU store maintenance that
/// works on any v1–v5 store without the xla feature or artifacts.
fn store_cmd(args: &Args) -> anyhow::Result<()> {
    use lorif::store::{inspect_store, recode_store, CodecId, RecodeOptions};
    let verb = args.positional.first().map(String::as_str).unwrap_or("");
    match verb {
        "inspect" => {
            let base = args.positional.get(1).ok_or_else(|| {
                anyhow::anyhow!("usage: lorif store inspect <base>")
            })?;
            print!("{}", inspect_store(std::path::Path::new(base))?);
            Ok(())
        }
        "recode" => {
            let base = args.positional.get(1).ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: lorif store recode <base> --out <base> --codec bf16|int8|int4"
                )
            })?;
            let out = args.get("out").ok_or_else(|| {
                anyhow::anyhow!("store recode needs --out <base> (in-place is refused)")
            })?;
            // every omitted knob (codec included) keeps the source
            // store's setting
            let mut opts = RecodeOptions {
                codec: args.get("codec").map(CodecId::parse).transpose()?,
                shards: args.get_usize("shards")?,
                summary_chunk: args.get_usize("summary-chunk")?,
                cluster: args.get_usize("cluster")?,
                ..Default::default()
            };
            if let Some(cs) = args.get_usize("chunk-size")? {
                opts.chunk_size = cs;
            }
            let rep = recode_store(
                std::path::Path::new(base),
                std::path::Path::new(out),
                &opts,
            )?;
            println!(
                "recoded {} {} examples: {} -> {} (v{}) in {:.2}s",
                rep.kind.as_str(),
                rep.n_examples,
                rep.src_codec.as_str(),
                rep.dst_codec.as_str(),
                rep.version,
                rep.wall.as_secs_f64()
            );
            println!(
                "on disk: {:.3} MB -> {:.3} MB ({:.2}x smaller) | shards {} | summary grid {} \
                 | cluster {}",
                rep.src_bytes as f64 / 1e6,
                rep.dst_bytes as f64 / 1e6,
                rep.shrink(),
                rep.shards.as_ref().map_or(1, Vec::len),
                rep.summary_chunk
                    .map_or("off".to_string(), |g| g.to_string()),
                rep.cluster.map_or("off".to_string(), |k| format!("k={k}"))
            );
            print!("{}", inspect_store(std::path::Path::new(out))?);
            Ok(())
        }
        other => anyhow::bail!("unknown store subcommand '{other}' (inspect|recode)"),
    }
}

/// `lorif metrics dump` — print the process-wide telemetry registry as
/// Prometheus text exposition.  A fresh process prints the full schema
/// at zero (every family is pre-registered), which is what the CI
/// perf-smoke step greps; a long-lived embedder calls the library's
/// `telemetry::global()` directly, and a running server serves the same
/// text over `{"cmd":"metrics"}`.
fn metrics_cmd(args: &Args) -> anyhow::Result<()> {
    let verb = args.positional.first().map(String::as_str).unwrap_or("");
    match verb {
        "dump" => {
            // `--label k=v,k2=v2` stamps base labels on every sample —
            // the same label grammar the coordinator's federation uses
            // (values are escaped per the Prometheus text format)
            match args.get("label") {
                Some(spec) => {
                    let labels = lorif::cli::parse_label_spec(spec)?;
                    let pairs: Vec<(&str, &str)> =
                        labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
                    print!(
                        "{}",
                        lorif::telemetry::global().render_prometheus_with(&pairs)
                    );
                }
                None => print!("{}", lorif::telemetry::global().render_prometheus()),
            }
            Ok(())
        }
        other => anyhow::bail!("unknown metrics subcommand '{other}' (usage: lorif metrics dump)"),
    }
}

/// `lorif slowlog --addr host:port [--json]` — fetch a running
/// server's (or coordinator's) slow-query log over the line protocol
/// and print the K slowest batches, slowest-first.
fn slowlog_cmd(args: &Args) -> anyhow::Result<()> {
    use lorif::util::json::Value;
    use std::io::{BufRead, BufReader, Write};

    let addr = args.get("addr").unwrap_or("127.0.0.1:7979");
    let stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    writeln!(stream, "{{\"cmd\": \"slowlog\"}}")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let v = Value::parse(line.trim())
        .map_err(|e| anyhow::anyhow!("unparseable reply from {addr}: {e}"))?;
    if let Some(msg) = v.get("error").and_then(Value::as_str) {
        anyhow::bail!("{addr}: {msg}");
    }
    let entries = v
        .get("slowlog")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow::anyhow!("{addr}: reply carries no slowlog array"))?;
    if args.has("json") {
        println!("{}", Value::Arr(entries.to_vec()));
        return Ok(());
    }
    if entries.is_empty() {
        println!("slowlog of {addr}: empty (no batches scored yet, or --slowlog 0)");
        return Ok(());
    }
    println!("slowlog of {addr}: {} slowest batches", entries.len());
    for (rank, e) in entries.iter().enumerate() {
        let f = |k: &str| e.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        let u = |k: &str| e.get(k).and_then(Value::as_usize).unwrap_or(0);
        let lat = e.get("latency");
        let lf = |k: &str| {
            lat.and_then(|l| l.get(k)).and_then(Value::as_f64).unwrap_or(0.0)
        };
        println!(
            "#{:<2} wall {:.3}s  batch {:<3} trace {:<6} at +{:.1}s  \
             (load {:.3}s compute {:.3}s pre {:.3}s, {:.1} MB read)",
            rank + 1,
            f("wall_s"),
            u("batch"),
            u("trace_id"),
            f("ts_s"),
            lf("load_s"),
            lf("compute_s"),
            lf("precondition_s"),
            lf("bytes_read") / 1e6,
        );
        if let Some(nodes) = e.get("nodes").and_then(Value::as_arr) {
            for n in nodes {
                let addr = n.get("addr").and_then(Value::as_str).unwrap_or("?");
                let wall = n.get("wall_s").and_then(Value::as_f64).unwrap_or(0.0);
                let retries = n.get("retries").and_then(Value::as_usize).unwrap_or(0);
                let failover =
                    n.get("failover").and_then(Value::as_bool).unwrap_or(false);
                let proactive =
                    n.get("proactive").and_then(Value::as_bool).unwrap_or(false);
                let mut flags = String::new();
                if proactive {
                    flags.push_str(" proactive-failover");
                } else if failover {
                    flags.push_str(" failover");
                }
                if retries > 0 {
                    flags.push_str(&format!(" retries={retries}"));
                }
                println!("     node {addr}: {wall:.3}s{flags}");
            }
        }
    }
    Ok(())
}

/// `lorif serve --coordinator` — the scatter-gather front end.  Speaks
/// the same line protocol as a single server: clients send token rows;
/// each admitted batch is scattered to every shard node
/// (`--nodes host:port=shards[/replica],...`), the per-node top-k heaps
/// are gathered and merged with the executor's own reduction, so
/// answers are bit-for-bit what one process over the whole store would
/// return.  Pure CPU: no model runtime, no store, no artifacts.
fn serve_coordinator(args: &Args) -> anyhow::Result<()> {
    use lorif::query::{
        Fleet, FleetOptions, RemotePlane, Server, ServerConfig, ShardPlane, TokenSource,
        Topology,
    };
    use std::time::Duration;

    let spec = args.get("nodes").ok_or_else(|| {
        anyhow::anyhow!("--coordinator needs --nodes host:port=shards[/replica],...")
    })?;
    let topology = Topology::parse(spec, args.get_usize("total-shards")?)?;
    let io_timeout_ms = args.get_u64("io-timeout-ms")?.unwrap_or(0);
    let io_timeout = (io_timeout_ms > 0).then(|| Duration::from_millis(io_timeout_ms));
    // the fleet monitor: health probes + metrics federation over the
    // same topology the planes scatter to.  Sharing one Arc is what
    // lets scatter legs route PROACTIVELY around a probed-down primary
    // instead of paying --io-timeout-ms to discover it per batch.
    let defaults = FleetOptions::default();
    let fleet = Fleet::new(
        topology.clone(),
        FleetOptions {
            probe_interval: Duration::from_millis(
                args.get_u64("probe-interval-ms")?
                    .unwrap_or(defaults.probe_interval.as_millis() as u64),
            ),
            probe_timeout: Duration::from_millis(
                args.get_u64("probe-timeout-ms")?
                    .unwrap_or(defaults.probe_timeout.as_millis() as u64)
                    .max(1),
            ),
            scrape_interval: Duration::from_millis(
                args.get_u64("scrape-interval-ms")?
                    .unwrap_or(defaults.scrape_interval.as_millis() as u64),
            ),
            fail_threshold: args
                .get_u64("probe-failures")?
                .unwrap_or(defaults.fail_threshold as u64)
                .max(1) as u32,
            event_log: args.get("event-log").map(std::path::PathBuf::from),
        },
    )?;
    // one RemotePlane per scoring worker: batch N+1 scatters while
    // batch N is still in flight on the nodes
    let workers = args.get_usize("score-workers")?.unwrap_or(2).max(1);
    let planes: Vec<Box<dyn ShardPlane + Send>> = (0..workers)
        .map(|_| {
            Box::new(RemotePlane {
                topology: topology.clone(),
                io_timeout,
                fleet: Some(fleet.clone()),
            }) as Box<dyn ShardPlane + Send>
        })
        .collect();
    // admission validates tokens exactly as the nodes will; override
    // --vocab/--seq-len when fronting a store built for another model
    let source = TokenSource {
        vocab: args.get_usize("vocab")?.unwrap_or(lorif::model::spec::VOCAB),
        seq_len: args.get_usize("seq-len")?.unwrap_or(lorif::model::spec::SEQ_LEN),
    };
    let sc = ServerConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7979").to_string(),
        max_batch: args.get_usize("max-batch")?.unwrap_or(16),
        window_ms: args.get_u64("window-ms")?.unwrap_or(20),
        topk: args.get_usize("topk")?.unwrap_or(10),
        queue_cap: args.get_usize("queue-cap")?.unwrap_or(64),
        io_timeout_ms,
        shards_served: 0,
        slowlog_cap: args.get_usize("slowlog")?.unwrap_or(32),
    };
    log::info!(
        "coordinator on {} over {} node(s) / {} shard(s)",
        sc.addr,
        topology.nodes.len(),
        topology.total_shards
    );
    let mut server = Server::bind(sc)?;
    server.set_fleet(fleet);
    let summary = server.run_planes(source, planes)?;
    println!(
        "coordinated {} queries in {} batches ({} shed, {} failed, {} dropped at shutdown)",
        summary.served, summary.batches, summary.shed, summary.failed, summary.dropped
    );
    Ok(())
}

fn info(cfg: &Config) -> anyhow::Result<()> {
    let spec = cfg.tier.spec();
    println!(
        "tier {} | layers {} | d_model {} | params {}",
        cfg.tier.name(),
        spec.n_layers,
        spec.d_model,
        spec.param_count()
    );
    println!("f={} c={} r={} | D = {}", cfg.f, cfg.c, cfg.r, spec.total_proj_dim(cfg.f));
    println!(
        "store layout: {} shard(s), codec {} (quant-score {}), score threads {}, sink {}, \
         prune {} (summary grid {}, cluster {}), prefetch depth {}, chunk cache {}",
        cfg.shards,
        cfg.codec.as_str(),
        cfg.quant_score.as_str(),
        if cfg.score_threads == 0 { "auto".to_string() } else { cfg.score_threads.to_string() },
        cfg.score_sink.name(),
        cfg.prune.label(),
        if cfg.summary_chunk == 0 { "off".to_string() } else { cfg.summary_chunk.to_string() },
        if cfg.cluster == 0 { "off".to_string() } else { format!("k={}", cfg.cluster) },
        cfg.prefetch_depth,
        if cfg.chunk_cache_mb == 0 {
            "off".to_string()
        } else {
            format!("{} MB", cfg.chunk_cache_mb)
        }
    );
    // payload estimate under the configured codec (scale headers add a
    // few bytes per segment on top for int8/int4)
    let bpv = cfg.codec.get().bytes_per_value();
    let dense = (spec.dense_floats_per_example(cfg.f) as f64 * bpv) as usize;
    let fact = (spec.factored_floats_per_example(cfg.f, cfg.c) as f64 * bpv) as usize;
    println!(
        "per-example storage ({}): dense ~{} B, factored ~{} B (ratio {:.1}x)",
        cfg.codec.as_str(),
        dense,
        fact,
        dense as f64 / fact as f64
    );
    println!(
        "index for n_train={}: dense ~{:.1} MB, factored ~{:.1} MB",
        cfg.n_train,
        dense as f64 * cfg.n_train as f64 / 1e6,
        fact as f64 * cfg.n_train as f64 / 1e6
    );
    for (i, l) in spec.tracked_layers().iter().enumerate() {
        let (d1, d2) = spec.proj_dims(cfg.f)[i];
        println!(
            "  layer {i}: {} [{}] ({}, {}) -> ({d1}, {d2})",
            l.name,
            l.module.as_str(),
            l.in_dim,
            l.out_dim
        );
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn prepared(
    cfg: Config,
) -> anyhow::Result<(Pipeline, lorif::corpus::Dataset, lorif::corpus::Dataset, Vec<f32>)> {
    let p = Pipeline::new(cfg)?;
    let (train, queries) = p.corpus()?;
    let params = p.base_params(&train)?;
    Ok((p, train, queries, params))
}

#[cfg(feature = "xla")]
fn build_index(cfg: Config, args: &Args) -> anyhow::Result<()> {
    let (p, train, _, params) = prepared(cfg)?;
    let lit = p.params_literal(&params)?;
    let dense = args.get("stores").map(|s| s.contains("dense")).unwrap_or(true);
    let opts = Stage1Options { write_factored: true, write_dense: dense, write_embeddings: true };
    let rep = p.stage1(&lit, &train, opts)?;
    println!(
        "stage 1: {} examples in {:.1}s ({} shard(s)) -> {:?}",
        rep.n_examples,
        rep.wall.as_secs_f64(),
        p.cfg.shards,
        p.cfg.index_dir()
    );
    let (curv, d2) = p.stage2_lorif()?;
    println!(
        "stage 2: rSVD r={} in {:.1}s (curvature memory {:.2} MB, O(Dr))",
        p.cfg.r,
        d2.as_secs_f64(),
        curv.memory_floats() as f64 * 4.0 / 1e6
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn make_query_grads(
    p: &Pipeline,
    params: &[f32],
    queries: &lorif::corpus::Dataset,
) -> anyhow::Result<lorif::attribution::QueryGrads> {
    let lit = p.params_literal(params)?;
    p.query_grads(&lit, queries)
}

/// Score the query set with a named method; returns scores + topk +
/// latency.  `sink` selects the engine's score sink — with
/// `SinkMode::TopK` the result carries no score matrix (O(Nq·k) memory).
#[cfg(feature = "xla")]
pub fn score_with_method(
    p: &Pipeline,
    method: Method,
    params: &[f32],
    train: &lorif::corpus::Dataset,
    queries: &lorif::corpus::Dataset,
    k: usize,
    sink: lorif::attribution::SinkMode,
) -> anyhow::Result<lorif::query::QueryResult> {
    let lit = p.params_literal(params)?;
    match method {
        Method::RepSim => {
            app::ensure_embeddings(p, &lit, train)?;
            let scorer = app::build_repsim_scorer(p, &lit, queries)?;
            let qg = make_query_grads(p, params, queries)?;
            let mut e = QueryEngine::new(scorer, k);
            e.topk_threads = p.cfg.score_threads;
            e.sink = sink;
            e.run(&qg)
        }
        Method::Ekfac => {
            let extractor = GradExtractor::new(&p.rt, p.cfg.tier, 1, 1)?;
            let scorer = app::build_ekfac_scorer(p, &extractor, &lit, train, 512)?;
            let qg = lorif::attribution::QueryGrads::extract(&p.rt, &extractor, &lit, queries)?;
            let mut e = QueryEngine::new(scorer, k);
            e.topk_threads = p.cfg.score_threads;
            e.sink = sink;
            e.run(&qg)
        }
        _ => {
            let scorer = app::build_store_scorer(p, method)?;
            let qg = make_query_grads(p, params, queries)?;
            let mut e = QueryEngine::new(scorer, k);
            e.topk_threads = p.cfg.score_threads;
            e.sink = sink;
            e.run(&qg)
        }
    }
}

#[cfg(feature = "xla")]
fn query(cfg: Config, args: &Args) -> anyhow::Result<()> {
    let method = Method::parse(args.get("method").unwrap_or("lorif"))?;
    let k = args.get_usize("topk")?.unwrap_or(10);
    let (p, train, queries, params) = prepared(cfg)?;
    // ensure index
    let lit = p.params_literal(&params)?;
    p.stage1(
        &lit,
        &train,
        Stage1Options { write_dense: method.needs_dense_store(), ..Default::default() },
    )?;
    let res = score_with_method(&p, method, &params, &train, &queries, k, p.cfg.score_sink)?;
    println!(
        "{}: {} queries x {} train | {:.3}s wall (load {:.3}s compute {:.3}s pre {:.3}s \
         CPU) | {:.1} MB read ({:.1} MB cached), {:.1} MB pruned",
        method.name(),
        queries.len(),
        train.len(),
        res.latency.wall_s,
        res.latency.load_s,
        res.latency.compute_s,
        res.latency.precondition_s,
        res.latency.bytes_read as f64 / 1e6,
        res.latency.bytes_from_cache as f64 / 1e6,
        res.latency.bytes_skipped as f64 / 1e6
    );
    let show = args.get_usize("show")?.unwrap_or(3).min(queries.len());
    let tm = p.topic_model();
    for q in 0..show {
        let top = &res.topk[q];
        println!(
            "query {q} (topic {}): top-{k} = {:?}",
            queries.topics[q],
            top.iter().map(|&t| format!("{t}[t{}]", train.topics[t])).collect::<Vec<_>>()
        );
        let rel = lorif::eval::judge::relevance(&tm, &queries, &train, q, top[0]);
        println!("  judge relevance of top-1: {rel}/5");
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn serve(cfg: Config, args: &Args) -> anyhow::Result<()> {
    let method = Method::parse(args.get("method").unwrap_or("lorif"))?;
    anyhow::ensure!(
        !matches!(method, Method::Ekfac | Method::RepSim),
        "serve supports the store-backed methods"
    );
    let (p, train, _, params) = prepared(cfg)?;
    let lit = p.params_literal(&params)?;
    p.stage1(
        &lit,
        &train,
        Stage1Options { write_dense: method.needs_dense_store(), ..Default::default() },
    )?;
    // node mode (`--node [--node-shards 0-2]`): serve only a subset of
    // the store's manifest shards.  Subset spans keep their GLOBAL
    // offsets, so this node's heap entries carry original example
    // indices a coordinator can merge without translation.
    let subset = if args.has("node") {
        args.get("node-shards").map(lorif::query::parse_shard_list).transpose()?
    } else {
        anyhow::ensure!(
            args.get("node-shards").is_none(),
            "--node-shards needs --node (shard-node serving mode)"
        );
        None
    };
    // a pool of scoring workers sharing one Arc'd store + chunk cache;
    // batch N+1's gradient extraction overlaps batch N's store pass
    let workers = args.get_usize("score-workers")?.unwrap_or(2).max(1);
    let scorers = app::build_store_scorer_pool_subset(&p, method, workers, subset.as_deref())?;
    let extractor = GradExtractor::new(&p.rt, p.cfg.tier, p.cfg.f, p.cfg.c)?;
    let sc = ServerConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7979").to_string(),
        max_batch: args.get_usize("max-batch")?.unwrap_or(16),
        window_ms: args.get_u64("window-ms")?.unwrap_or(20),
        topk: args.get_usize("topk")?.unwrap_or(10),
        queue_cap: args.get_usize("queue-cap")?.unwrap_or(64),
        io_timeout_ms: args.get_u64("io-timeout-ms")?.unwrap_or(0),
        shards_served: subset.as_ref().map_or(0, Vec::len),
        slowlog_cap: args.get_usize("slowlog")?.unwrap_or(32),
    };
    if let Some(s) = &subset {
        log::info!("node mode: serving manifest shards {s:?}");
    }
    let source =
        lorif::query::server::XlaGradSource { rt: &p.rt, extractor: &extractor, params: &lit };
    let summary = lorif::query::serve(source, scorers, sc)?;
    println!(
        "served {} queries in {} batches ({} shed, {} failed, {} dropped at shutdown)",
        summary.served, summary.batches, summary.shed, summary.failed, summary.dropped
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn eval_lds(cfg: Config, args: &Args) -> anyhow::Result<()> {
    let method = Method::parse(args.get("method").unwrap_or("lorif"))?;
    let (p, train, queries, params) = prepared(cfg)?;
    let lit = p.params_literal(&params)?;
    p.stage1(&lit, &train, Stage1Options::default())?;
    // LDS correlates against every score, so force the full sink here
    let res = score_with_method(
        &p,
        method,
        &params,
        &train,
        &queries,
        10,
        lorif::attribution::SinkMode::Full,
    )?;
    let mut proto = LdsProtocol::default();
    if let Some(m) = args.get_usize("subsets")? {
        proto.n_subsets = m;
    }
    if let Some(s) = args.get_usize("retrain-steps")? {
        proto.steps = s;
    }
    let actuals = LdsActuals::get(&p, &proto, &train, &queries)?;
    let scores = res.scores.as_ref().expect("full sink requested");
    let (lds, ci) = actuals.lds(scores);
    println!(
        "{} LDS = {:.4} ± {:.4} (M={} subsets, query wall {:.3}s, index {:.1} MB)",
        method.name(),
        lds,
        ci,
        proto.n_subsets,
        res.latency.wall_s,
        res.latency.bytes_read as f64 / 1e6,
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn eval_tailpatch(cfg: Config, args: &Args) -> anyhow::Result<()> {
    let method = Method::parse(args.get("method").unwrap_or("lorif"))?;
    let (p, train, queries, params) = prepared(cfg)?;
    let lit = p.params_literal(&params)?;
    p.stage1(&lit, &train, Stage1Options::default())?;
    let mut proto = TailPatchProtocol::default();
    if let Some(k) = args.get_usize("k")? {
        proto.k = k;
    }
    if let Some(lr) = args.get_f32("patch-lr")? {
        proto.lr = lr;
    }
    // tail-patch only needs the top-k proponents: any sink works
    let res =
        score_with_method(&p, method, &params, &train, &queries, proto.k, p.cfg.score_sink)?;
    let scores = lorif::eval::tail_patch(&p, &params, &train, &queries, &res.topk, proto)?;
    let (mean, ci) = lorif::eval::tail_patch_mean(&scores);
    println!(
        "{} tail-patch = {:.3} ± {:.3} (k={}, lr={}, query wall {:.3}s)",
        method.name(),
        mean,
        ci,
        proto.k,
        proto.lr,
        res.latency.wall_s
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn judge(cfg: Config, args: &Args) -> anyhow::Result<()> {
    let (p, train, queries, params) = prepared(cfg)?;
    let lit = p.params_literal(&params)?;
    p.stage1(&lit, &train, Stage1Options::default())?;
    let tm = p.topic_model();
    let a = Method::parse(args.get("method-a").unwrap_or("lorif"))?;
    let b = Method::parse(args.get("method-b").unwrap_or("logra"))?;
    let ra = score_with_method(&p, a, &params, &train, &queries, 1, p.cfg.score_sink)?;
    let rb = score_with_method(&p, b, &params, &train, &queries, 1, p.cfg.score_sink)?;
    let top_a: Vec<usize> = ra.topk.iter().map(|t| t[0]).collect();
    let top_b: Vec<usize> = rb.topk.iter().map(|t| t[0]).collect();
    let sa = lorif::eval::judge::judge_top1(&tm, &queries, &train, &top_a);
    let sb = lorif::eval::judge::judge_top1(&tm, &queries, &train, &top_b);
    let (aw, bw, tie) = lorif::eval::judge::preference(&tm, &queries, &train, &top_a, &top_b);
    println!(
        "judge avg relevance: {} {:.2} vs {} {:.2}",
        a.name(),
        sa.avg_score,
        b.name(),
        sb.avg_score
    );
    println!(
        "preference: {} {:.1}% / {} {:.1}% / tie {:.1}%",
        a.name(),
        100.0 * aw,
        b.name(),
        100.0 * bw,
        100.0 * tie
    );
    Ok(())
}

fn print_help() {
    println!(
        "lorif — low-rank influence functions (paper reproduction)\n\
         usage: lorif <subcommand> [flags]\n\
         subcommands: info store metrics gen-corpus train build-index query serve\n\
                      eval-lds eval-tailpatch judge\n\
         store tools: store inspect <base>\n\
                      store recode <base> --out <base> --codec bf16|int8|int4\n\
                                   [--shards S] [--summary-chunk G] [--cluster K]\n\
         telemetry:   metrics dump [--label k=v,...]   (Prometheus text)\n\
                      slowlog --addr A [--json]   (K slowest batches)\n\
                      --trace-out PATH   (Chrome trace-event spans, Perfetto)\n\
         common flags: --tier small|medium|large --f N --c N --r N\n\
                       --n-train N --n-query N --seed S --method NAME\n\
                       --shards S --score-threads T --sink full|topk\n\
                       --prune on|off|slack=x|recall=x --prefetch-depth N\n\
                       --summary-chunk N --cluster K --chunk-cache-mb N\n\
                       --codec bf16|int8|int4 --quant-score on|off|auto\n\
                       --work-dir DIR --artifacts-dir DIR --trace-out PATH\n\
         serve flags:  --addr A --max-batch N --window-ms N --topk K\n\
                       --score-workers N --queue-cap N --io-timeout-ms N\n\
                       --slowlog K   (slow-query ring capacity, default 32)\n\
         distributed:  serve --node [--node-shards 0-2+5]   (shard node)\n\
                       serve --coordinator --nodes addr=shards[/replica],...\n\
                             [--total-shards N] [--vocab N] [--seq-len N]\n\
                             [--probe-interval-ms N] [--probe-timeout-ms N]\n\
                             [--probe-failures N] [--scrape-interval-ms N]\n\
                             [--event-log PATH]   (fleet monitor knobs)\n\
         pure-CPU builds support `info`, `store`, `metrics`, and `serve\n\
         --coordinator`; the rest need --features xla\n\
         see rust/README.md for a walkthrough."
    );
}

//! Chunk-summary pruning index: skip store I/O that cannot reach the
//! top-k.
//!
//! LoRIF's query bottleneck is streaming the projected-gradient store
//! (paper §1, bottleneck *i*).  After the sharded reader (PR 1) and the
//! streaming top-k sinks (PR 2), every top-k query still read 100% of
//! the store bytes — the sink bounded memory, not reads.  This module
//! adds the missing half: a sidecar **summary index** written at
//! stage-1 time (`<base>.summaries`, manifest v3) holding per-chunk,
//! per-layer bounds — max row norm, centroid, and centroid-residual
//! radius — from which a Cauchy–Schwarz upper bound on ANY score in a
//! chunk can be computed against the preconditioned query block.  Once
//! the per-query top-k heaps establish a threshold, chunks whose bound
//! cannot beat it are skipped without touching the disk.
//!
//! * [`summary`] — the sidecar data model, its binary format, the
//!   writer-side [`summary::SummaryBuilder`], and the per-chunk bound
//!   statistics ([`summary::summarize_chunk`]).
//! * [`prune`] — query-side bound evaluation ([`prune::QueryBounds`]),
//!   the [`prune::ChunkPruner`] handed to the streaming executor, and
//!   the `--prune on|off|slack=x` mode knob.
//!
//! Exactness: in `on` (exact) mode, pruned top-k results are provably
//! identical to a full scan — see the module docs in [`prune`] for the
//! argument (soundness of the bound + ascending-index tie-breaking
//! within a shard).  `slack=x` deflates the bound by a relative factor,
//! trading recall for latency.

pub mod prune;
pub mod summary;

pub use prune::{ChunkPruner, PruneMode, QueryBounds};
pub use summary::{
    summarize_chunk, ChunkSummary, LayerSummary, StoreSummaries, SummaryBuilder,
    DEFAULT_SUMMARY_CHUNK,
};

//! Query-side bound evaluation and the pruning mode knob.
//!
//! Every store kernel's score is (a per-layer sum of) an inner product
//! between an effective dense train vector `t_n` and an effective query
//! vector `y_q` fixed at precondition time:
//!
//!   * GradDot:   `t_n` = stored row,          `y_q = g_q`
//!   * LoGRA:     `t_n` = stored row,          `y_q = K⁻¹ g_q`
//!   * TrackStar: `t_n` = stored row / ‖·‖,    `y_q = K⁻¹ g_q / ‖·‖`
//!   * LoRIF:     `t_n = U_n V_nᵀ` (implicit), `y_q = g̃_q/λ − V_r ŵ_q`
//!
//! For a chunk with per-layer summary (max row norm `M`, centroid `c`,
//! radius `R`), two sound upper bounds on `⟨t_n, y⟩` hold for every
//! example in the chunk:
//!
//!   Cauchy–Schwarz:   ⟨t_n, y⟩ ≤ M · ‖y‖
//!   centroid + C–S:   ⟨t_n, y⟩ = ⟨c, y⟩ + ⟨t_n − c, y⟩ ≤ ⟨c, y⟩ + R · ‖y‖
//!
//! [`QueryBounds::upper_bound`] takes the tighter of the two per layer
//! and sums over layers, padding with a small float-slack term (scaled
//! by the C–S bound and the layer dimension) that dominates the f32
//! summation-order differences between this bound and the kernels'
//! GEMMs — which is what makes exact-mode pruning safe in floating
//! point, not just in real arithmetic.
//!
//! **Exactness argument** (`--prune on`): a chunk is skipped only when,
//! for every query, the (slack-free) bound is STRICTLY below the
//! current top-k threshold `t` (the k-th best score seen so far — the
//! shard's own heap, tightened by the cross-shard shared threshold,
//! see `query::parallel::SharedThreshold`).  Every example in a skipped
//! chunk then has score ≤ bound < t ≤ t_final, i.e. strictly below the
//! final k-th best score, so it cannot belong to the top-k under ANY
//! tie-breaking rule — which is what makes the argument hold for the
//! best-first (bound-ordered) visit order of `attribution::exec`, where
//! a skipped chunk may hold LOWER original indices than resident heap
//! entries and an `≤` test would wrongly discard an equal-scoring
//! lower-index example that wins the repo's tie-break (descending
//! score, ties toward the LOWER index).  Heaps push ORIGINAL (caller
//! coordinate) indices even on permuted v5 stores, so the (score,
//! index) total order — and with it the top-k — is independent of the
//! storage order and of the visit order.  Hence the pruned result is
//! bit-identical to an unclustered full scan, and the cross-shard merge
//! (`query::parallel::merge_topk`) is unchanged.  NaN scores rank above
//! +inf under `total_cmp`; chunks containing any non-finite record are
//! marked non-finite by the summarizer and are never skipped.
//!
//! **Recall mode** (`--prune recall=x`): chunk skipping stays exact
//! (strict bound test as above), but a shard may additionally STOP
//! early once, for every query, at least `ceil(x·k)` of its heap
//! entries provably cannot be displaced by any unvisited chunk (their
//! scores strictly exceed the best remaining bound).  The stop rule
//! only ever leaves unvisited chunks whose bounds trail the certified
//! entries, which on a clustered (v5) store is the long tail of
//! far-away clusters — the measured overlap@k at `recall=0.99` stays
//! ≥ 0.99 while reading a small fraction of the bytes
//! (`benches/perf_microbench.rs` persists the curve).
//!
//! **Interaction with the decoded-chunk cache** (`store::cache`): the
//! executor evaluates the skip test BEFORE any cache lookup, so a
//! chunk's residency never changes a pruning decision, a skipped chunk
//! never populates the cache, and a skip never invalidates an entry.
//! A pruned pass over a warm cache therefore skips exactly the chunks
//! a cold pruned pass would, and serves its reads from residency —
//! both properties are asserted in `tests/prop.rs` and the scorers'
//! unit tests.

use crate::linalg::Mat;

use super::summary::{ChunkSummary, StoreSummaries};

/// Config/CLI-level pruning mode (`--prune on|off|slack=x|recall=x`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PruneMode {
    /// Never skip (every chunk is read, as before this subsystem).
    Off,
    /// Exact: skip only provably unreachable chunks — results are
    /// identical to a full scan.
    Exact,
    /// Approximate: deflate the bound by `slack * |bound|` before the
    /// threshold comparison, trading recall for fewer reads (0 < x < 1).
    Slack(f32),
    /// Approximate: exact bound test, but each shard stops early once
    /// `ceil(x·k)` of its top-k entries are provably final (0 < x ≤ 1).
    /// The retrieval-tier knob — pairs with a clustered (v5) store.
    Recall(f32),
}

impl PruneMode {
    pub fn parse(s: &str) -> anyhow::Result<PruneMode> {
        match s {
            "off" => Ok(PruneMode::Off),
            "on" | "exact" => Ok(PruneMode::Exact),
            _ => {
                if let Some(x) = s.strip_prefix("recall=") {
                    let x: f32 = x
                        .parse()
                        .map_err(|e| anyhow::anyhow!("--prune recall: {e}"))?;
                    anyhow::ensure!(
                        x > 0.0 && x <= 1.0,
                        "prune recall target must be in (0, 1], got {x}"
                    );
                    return Ok(PruneMode::Recall(x));
                }
                let Some(x) = s.strip_prefix("slack=") else {
                    anyhow::bail!("unknown prune mode '{s}' (on|off|slack=x|recall=x)");
                };
                let x: f32 = x
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--prune slack: {e}"))?;
                anyhow::ensure!(
                    (0.0..1.0).contains(&x),
                    "prune slack must be in [0, 1), got {x}"
                );
                Ok(if x == 0.0 { PruneMode::Exact } else { PruneMode::Slack(x) })
            }
        }
    }

    /// The `--prune` spelling of this mode (config round-trip).
    pub fn label(&self) -> String {
        match self {
            PruneMode::Off => "off".to_string(),
            PruneMode::Exact => "on".to_string(),
            PruneMode::Slack(x) => format!("slack={x}"),
            PruneMode::Recall(x) => format!("recall={x}"),
        }
    }

    /// `None` when pruning is disabled, otherwise the slack factor
    /// (0 for exact and recall modes, whose bound tests stay exact).
    pub fn slack(&self) -> Option<f32> {
        match self {
            PruneMode::Off => None,
            PruneMode::Exact => Some(0.0),
            PruneMode::Slack(x) => Some(*x),
            PruneMode::Recall(_) => Some(0.0),
        }
    }

    /// The per-shard early-stop recall target, when this mode has one.
    /// `Recall(1.0)` still reports a target: the stop rule at x = 1
    /// fires only when EVERY entry is certified final, which can still
    /// beat the plain exact scan on a clustered store (certification
    /// uses strict dominance, not bound exhaustion).
    pub fn recall(&self) -> Option<f32> {
        match self {
            PruneMode::Recall(x) => Some(*x),
            _ => None,
        }
    }
}

/// Per-query bound state over the effective query blocks: row norms are
/// precomputed once, centroid dots are evaluated per (chunk, query).
pub struct QueryBounds {
    /// per layer: `(n_query, D_l)` effective query vectors
    pub blocks: Vec<Mat>,
    /// per layer, per query: L2 norm of the block row
    norms: Vec<Vec<f32>>,
    /// bound evaluations performed through this instance — a local
    /// atomic (shared bounds are read from several shard workers), read
    /// once per pass by the executor and published into the registry's
    /// `lorif_prune_bound_evals_total`
    evals: std::sync::atomic::AtomicU64,
}

impl QueryBounds {
    pub fn new(blocks: Vec<Mat>) -> QueryBounds {
        let norms = blocks
            .iter()
            .map(|m| {
                (0..m.rows)
                    .map(|q| {
                        m.row(q)
                            .iter()
                            .map(|&x| x as f64 * x as f64)
                            .sum::<f64>()
                            .sqrt() as f32
                    })
                    .collect()
            })
            .collect();
        QueryBounds { blocks, norms, evals: std::sync::atomic::AtomicU64::new(0) }
    }

    /// Bound evaluations performed so far (see the `evals` field).
    pub fn evals(&self) -> u64 {
        self.evals.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Sound upper bound on `Σ_l ⟨t_n^l, y_q^l⟩` over every example `n`
    /// in the summarized chunk.  Returns +inf for non-finite chunks and
    /// NaN (never skippable: `NaN <= t` is false) when the query side
    /// is non-finite.
    pub fn upper_bound(&self, s: &ChunkSummary, q: usize) -> f32 {
        self.evals.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if !s.finite {
            return f32::INFINITY;
        }
        let mut total = 0.0f32;
        for (l, ls) in s.layers.iter().enumerate() {
            let y = self.blocks[l].row(q);
            debug_assert_eq!(y.len(), ls.centroid.len());
            let ny = self.norms[l][q];
            let cs = ls.max_row_norm * ny;
            // centroid dot in f64: the slack term then only has to
            // cover the kernels' f32 GEMM error, not this bound's own
            let mut cdot = 0.0f64;
            for (a, b) in ls.centroid.iter().zip(y) {
                cdot += *a as f64 * *b as f64;
            }
            let cb = cdot as f32 + ls.radius * ny;
            if cs.is_nan() || cb.is_nan() {
                return f32::NAN;
            }
            // float slack: relative to the C–S bound (an upper bound on
            // any per-layer magnitude) and growing with the dimension,
            // dominating worst-case f32 dot-product rounding.  The base
            // constant is sized for kernels whose two score terms nearly
            // cancel (LoRIF's Woodbury subtraction computes large terms
            // whose difference is ‖y‖-sized): even at r = 128 the pad
            // exceeds the f32 error of the cancelled sum by >10x.
            let slack = cs * (3e-3 + 1e-6 * y.len() as f32);
            total += cs.min(cb) + slack;
        }
        total
    }
}

/// The executor-side pruning context: the store's summary grid plus the
/// configured slack.  Built by `attribution::exec::execute` for top-k
/// passes over stores that carry a sidecar.
pub struct ChunkPruner<'a> {
    pub summaries: &'a StoreSummaries,
    /// relative bound deflation (0 = exact)
    pub slack: f32,
}

impl ChunkPruner<'_> {
    /// The read-granularity the pruned pass must use (the summary grid).
    pub fn chunk_size(&self) -> usize {
        self.summaries.chunk_size
    }

    /// Summary for the chunk at `(start, count)`, or `None` (never
    /// skip) when the grid disagrees with the requested span.
    pub fn summary_for(&self, start: usize, count: usize) -> Option<&ChunkSummary> {
        self.summaries.find(start).filter(|s| s.count == count)
    }

    /// Deflate a bound by the configured slack before the threshold
    /// comparison (identity in exact mode; NaN and, under slack, +inf
    /// deflate to NaN — both compare false against any threshold, so
    /// non-finite chunks are read either way).
    pub fn deflate(&self, u: f32) -> f32 {
        if self.slack == 0.0 {
            u
        } else {
            u - self.slack * u.abs()
        }
    }
}

/// Publish one pass's pruning outcome into a metrics registry: how many
/// bound evaluations ran and what they bought (chunks/bytes never
/// read).  The byte count is the same quantity `StreamStats::publish`
/// feeds `lorif_store_bytes_skipped_total` — mirrored here under the
/// prune family so the cost/benefit of the sidecar is readable without
/// joining against the store family.
pub fn publish_prune_outcome(
    reg: &crate::telemetry::Registry,
    bound_evals: u64,
    chunks_skipped: u64,
    bytes_skipped: u64,
) {
    reg.prune_bound_evals.add(bound_evals);
    reg.prune_chunks_skipped.add(chunks_skipped);
    reg.prune_bytes_skipped.add(bytes_skipped);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::summary::summarize_chunk;
    use crate::store::{Chunk, ChunkLayer, StoreKind, StoreMeta};
    use crate::util::prng::Rng;

    #[test]
    fn prune_mode_parses_and_labels() {
        assert_eq!(PruneMode::parse("off").unwrap(), PruneMode::Off);
        assert_eq!(PruneMode::parse("on").unwrap(), PruneMode::Exact);
        assert_eq!(PruneMode::parse("slack=0.25").unwrap(), PruneMode::Slack(0.25));
        assert_eq!(PruneMode::parse("slack=0").unwrap(), PruneMode::Exact);
        assert_eq!(PruneMode::parse("recall=0.99").unwrap(), PruneMode::Recall(0.99));
        assert_eq!(PruneMode::parse("recall=1").unwrap(), PruneMode::Recall(1.0));
        assert!(PruneMode::parse("slack=1.5").is_err());
        assert!(PruneMode::parse("slack=-0.1").is_err());
        assert!(PruneMode::parse("recall=0").is_err());
        assert!(PruneMode::parse("recall=1.01").is_err());
        assert!(PruneMode::parse("maybe").is_err());
        for m in [
            PruneMode::Off,
            PruneMode::Exact,
            PruneMode::Slack(0.5),
            PruneMode::Recall(0.99),
        ] {
            assert_eq!(PruneMode::parse(&m.label()).unwrap(), m);
        }
        assert_eq!(PruneMode::Off.slack(), None);
        assert_eq!(PruneMode::Exact.slack(), Some(0.0));
        // recall mode's bound test stays exact; the approximation lives
        // in the early-stop rule, reported separately
        assert_eq!(PruneMode::Recall(0.99).slack(), Some(0.0));
        assert_eq!(PruneMode::Recall(0.99).recall(), Some(0.99));
        assert_eq!(PruneMode::Exact.recall(), None);
    }

    #[test]
    fn upper_bound_dominates_every_true_score() {
        // random chunks x random queries: the bound is never below the
        // exact inner product of any (example, query) pair
        let mut rng = Rng::new(17);
        for trial in 0..20 {
            let (b, nq, d) = (1 + rng.below(12), 1 + rng.below(4), 2 + rng.below(20));
            let g = crate::linalg::Mat::random_normal(b, d, 1.0, &mut rng);
            let yq = crate::linalg::Mat::random_normal(nq, d, 1.0, &mut rng);
            let meta = StoreMeta {
                kind: StoreKind::Dense,
                tier: "small".into(),
                f: 4,
                c: 1,
                layers: vec![(1, d)],
                n_examples: b,
                shards: None,
                summary_chunk: None,
                codec: crate::store::CodecId::Bf16,
            };
            let chunk = Chunk {
                start: 0,
                count: b,
                layers: vec![ChunkLayer::Dense { g: g.clone() }],
                encoded: None,
                io_time: std::time::Duration::ZERO,
            };
            let s = summarize_chunk(&meta, &chunk).unwrap();
            let bounds = QueryBounds::new(vec![yq.clone()]);
            for q in 0..nq {
                let u = bounds.upper_bound(&s, q);
                for n in 0..b {
                    let score: f32 =
                        g.row(n).iter().zip(yq.row(q)).map(|(a, b)| a * b).sum();
                    assert!(score <= u, "trial {trial}: score {score} > bound {u}");
                }
            }
        }
    }

    #[test]
    fn clustered_chunk_gets_a_tight_centroid_bound() {
        // rows tightly packed around a centroid far from the query
        // direction: the centroid bound must be far below Cauchy–Schwarz
        let mut rng = Rng::new(23);
        let d = 16;
        let mut g = crate::linalg::Mat::zeros(8, d);
        for n in 0..8 {
            g.row_mut(n)[0] = -5.0 + 0.01 * rng.normal() as f32;
        }
        let mut yq = crate::linalg::Mat::zeros(1, d);
        yq.row_mut(0)[0] = 1.0;
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: vec![(1, d)],
            n_examples: 8,
            shards: None,
            summary_chunk: None,
            codec: crate::store::CodecId::Bf16,
        };
        let chunk = Chunk {
            start: 0,
            count: 8,
            layers: vec![ChunkLayer::Dense { g }],
            encoded: None,
            io_time: std::time::Duration::ZERO,
        };
        let s = summarize_chunk(&meta, &chunk).unwrap();
        let bounds = QueryBounds::new(vec![yq]);
        let u = bounds.upper_bound(&s, 0);
        // true scores are ~-5; C–S alone would say +5
        assert!(u < -4.0, "bound {u} not using the centroid");
    }

    #[test]
    fn non_finite_chunks_are_never_skippable() {
        let mut rng = Rng::new(29);
        let mut g = crate::linalg::Mat::random_normal(4, 6, 1.0, &mut rng);
        *g.at_mut(1, 2) = f32::INFINITY;
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: vec![(2, 3)],
            n_examples: 4,
            shards: None,
            summary_chunk: None,
            codec: crate::store::CodecId::Bf16,
        };
        let chunk = Chunk {
            start: 0,
            count: 4,
            layers: vec![ChunkLayer::Dense { g }],
            encoded: None,
            io_time: std::time::Duration::ZERO,
        };
        let s = summarize_chunk(&meta, &chunk).unwrap();
        let bounds =
            QueryBounds::new(vec![crate::linalg::Mat::random_normal(1, 6, 1.0, &mut rng)]);
        assert_eq!(bounds.upper_bound(&s, 0), f32::INFINITY);
        let pr = ChunkPruner { summaries: &StoreSummaries { chunk_size: 4, chunks: vec![] }, slack: 0.0 };
        // +inf deflates to +inf; NaN comparisons are never "skippable"
        assert_eq!(pr.deflate(f32::INFINITY), f32::INFINITY);
        assert!(!(pr.deflate(f32::NAN) <= 1.0e30));
    }

    #[test]
    fn bound_evals_are_counted_and_publish_into_the_prune_family() {
        let mut rng = Rng::new(31);
        let bounds =
            QueryBounds::new(vec![crate::linalg::Mat::random_normal(2, 6, 1.0, &mut rng)]);
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: vec![(2, 3)],
            n_examples: 4,
            shards: None,
            summary_chunk: None,
            codec: crate::store::CodecId::Bf16,
        };
        let chunk = Chunk {
            start: 0,
            count: 4,
            layers: vec![ChunkLayer::Dense {
                g: crate::linalg::Mat::random_normal(4, 6, 1.0, &mut rng),
            }],
            encoded: None,
            io_time: std::time::Duration::ZERO,
        };
        let s = summarize_chunk(&meta, &chunk).unwrap();
        assert_eq!(bounds.evals(), 0);
        let _ = bounds.upper_bound(&s, 0);
        let _ = bounds.upper_bound(&s, 1);
        assert_eq!(bounds.evals(), 2);

        let reg = crate::telemetry::Registry::new();
        publish_prune_outcome(&reg, bounds.evals(), 3, 4096);
        assert_eq!(reg.prune_bound_evals.get(), 2);
        assert_eq!(reg.prune_chunks_skipped.get(), 3);
        assert_eq!(reg.prune_bytes_skipped.get(), 4096);
    }

    #[test]
    fn slack_deflates_toward_zero() {
        let pr = ChunkPruner {
            summaries: &StoreSummaries { chunk_size: 4, chunks: vec![] },
            slack: 0.25,
        };
        assert!((pr.deflate(4.0) - 3.0).abs() < 1e-6);
        assert!((pr.deflate(-4.0) - (-5.0)).abs() < 1e-6);
        let exact = ChunkPruner {
            summaries: &StoreSummaries { chunk_size: 4, chunks: vec![] },
            slack: 0.0,
        };
        assert_eq!(exact.deflate(4.0), 4.0);
    }
}

//! Per-chunk summary statistics and the `<base>.summaries` sidecar.
//!
//! A summary chunk covers a fixed-stride run of consecutive records
//! (the grid restarts at every shard boundary, so a chunk never
//! straddles two data files).  Per chunk we keep, for each layer:
//!
//!   * `max_row_norm` — max over examples of the L2 norm of the layer's
//!     effective dense train vector (the stored row for Dense records;
//!     `‖U Vᵀ‖_F` for Factored records, computed from the (c × c)
//!     factor Grams without materializing the product);
//!   * `centroid` — the mean effective dense vector (`d1·d2` floats);
//!   * `radius` — max over examples of `‖t_n − centroid‖`.
//!
//! plus whole-record `min_norm`/`max_norm` (all layers concatenated),
//! which normalizing scorers (TrackStar) need to bound their
//! denominator.  All statistics are computed from the **bf16-decoded**
//! record bytes — exactly the values the query path scores — and are
//! accumulated in f64, then inflated by a small safety factor on the
//! way to f32, so a stored bound is never below the true one.
//!
//! The sidecar is versioned through the store manifest: a manifest with
//! `"version": 3` carries a `summary_chunk` field and requires the
//! `.summaries` file; v1/v2 manifests have no sidecar and scorers fall
//! back to a full scan.

use std::io::{Read, Write};
use std::path::Path;

use crate::store::reader::decode_chunk;
use crate::store::{Chunk, ChunkLayer, StoreMeta};

/// Default records per summary chunk (matches the scorers' default
/// streaming chunk size, so one skip saves one read).
pub const DEFAULT_SUMMARY_CHUNK: usize = 512;

/// Multiplicative safety inflation applied to stored norms and radii:
/// absorbs the f64→f32 rounding of the statistics themselves.
const UP: f64 = 1.0 + 1e-5;
/// Inflation for the whole-record norm window (TrackStar divides by
/// these, so they get a wider margin: the kernel accumulates its norms
/// in f32, whose error grows with the record dimension).
const NORM_UP: f64 = 1.0 + 1e-3;

/// Bound statistics for one layer of one summary chunk.
#[derive(Clone, Debug)]
pub struct LayerSummary {
    /// max over examples of the effective dense row norm
    pub max_row_norm: f32,
    /// mean effective dense vector (`d1·d2` floats)
    pub centroid: Vec<f32>,
    /// max over examples of the distance to the centroid
    pub radius: f32,
}

/// Bound statistics for one summary chunk of consecutive records.
#[derive(Clone, Debug)]
pub struct ChunkSummary {
    /// global index of the chunk's first example
    pub start: usize,
    pub count: usize,
    /// min/max whole-record norm (all layers concatenated), deflated /
    /// inflated so dividing by them is sound in f32
    pub min_norm: f32,
    pub max_norm: f32,
    /// false when any statistic is non-finite (NaN/Inf records): bound
    /// evaluation then returns +inf and the chunk is always read
    pub finite: bool,
    pub layers: Vec<LayerSummary>,
}

impl ChunkSummary {
    fn compute_finite(&self) -> bool {
        self.min_norm.is_finite()
            && self.max_norm.is_finite()
            && self.layers.iter().all(|l| {
                l.max_row_norm.is_finite()
                    && l.radius.is_finite()
                    && l.centroid.iter().all(|x| x.is_finite())
            })
    }
}

/// Summarize one decoded chunk.  `meta` supplies the layer dims and
/// kind; the chunk must have been decoded from the same store.
pub fn summarize_chunk(meta: &StoreMeta, chunk: &Chunk) -> anyhow::Result<ChunkSummary> {
    let b = chunk.count;
    anyhow::ensure!(b > 0, "cannot summarize an empty chunk");
    anyhow::ensure!(chunk.layers.len() == meta.layers.len(), "layer count mismatch");
    let mut rec_norm2 = vec![0.0f64; b];
    let mut layers = Vec::with_capacity(meta.layers.len());
    for (l, &(d1, d2)) in meta.layers.iter().enumerate() {
        let d = d1 * d2;
        let (norms2, centroid, dots) = match &chunk.layers[l] {
            ChunkLayer::Dense { g } => dense_stats(g, b, d),
            ChunkLayer::Factored { u, v } => factored_stats(u, v, b, d1, d2, meta.c),
        };
        let cent_norm2: f64 = centroid.iter().map(|x| x * x).sum();
        let mut max_norm = 0.0f64;
        let mut max_rad = 0.0f64;
        let mut non_finite = false;
        for n in 0..b {
            rec_norm2[n] += norms2[n];
            if !norms2[n].is_finite() || !dots[n].is_finite() {
                non_finite = true;
                continue;
            }
            max_norm = max_norm.max(norms2[n].sqrt());
            // ‖t_n − c‖² = ‖t_n‖² − 2⟨t_n, c⟩ + ‖c‖² (clamped: f64
            // cancellation can dip fractionally below zero)
            let r2 = (norms2[n] - 2.0 * dots[n] + cent_norm2).max(0.0);
            max_rad = max_rad.max(r2.sqrt());
        }
        // a non-finite row poisons the whole layer: report +inf bounds
        // so the chunk is never pruned (NaN scores sort ABOVE +inf
        // under total_cmp, so no finite bound would be sound)
        let (mrn, rad) = if non_finite || !cent_norm2.is_finite() {
            (f32::INFINITY, f32::INFINITY)
        } else {
            (
                (max_norm * UP) as f32,
                (max_rad * UP + max_norm * 1e-6) as f32,
            )
        };
        layers.push(LayerSummary {
            max_row_norm: mrn,
            radius: rad,
            centroid: centroid.iter().map(|&x| x as f32).collect(),
        });
    }
    let mut min_norm = f64::INFINITY;
    let mut max_norm = 0.0f64;
    for &n2 in &rec_norm2 {
        let n = n2.sqrt();
        min_norm = min_norm.min(n);
        max_norm = max_norm.max(n);
    }
    let mut s = ChunkSummary {
        start: chunk.start,
        count: b,
        min_norm: ((min_norm / NORM_UP).max(0.0)) as f32,
        max_norm: (max_norm * NORM_UP) as f32,
        finite: true,
        layers,
    };
    s.finite = s.compute_finite();
    Ok(s)
}

/// Per-row squared norms, centroid, and per-row centroid dots for a
/// dense layer block.
fn dense_stats(g: &crate::linalg::Mat, b: usize, d: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut cent = vec![0.0f64; d];
    let mut norms2 = vec![0.0f64; b];
    for n in 0..b {
        let mut s = 0.0f64;
        for (j, &x) in g.row(n).iter().enumerate() {
            let x = x as f64;
            cent[j] += x;
            s += x * x;
        }
        norms2[n] = s;
    }
    for c in cent.iter_mut() {
        *c /= b as f64;
    }
    let mut dots = vec![0.0f64; b];
    for n in 0..b {
        let mut s = 0.0f64;
        for (j, &x) in g.row(n).iter().enumerate() {
            s += x as f64 * cent[j];
        }
        dots[n] = s;
    }
    (norms2, cent, dots)
}

/// Same statistics for a factored layer block, never materializing a
/// per-row `d1 × d2` product:
///   * `‖U Vᵀ‖_F² = ⟨UᵀU, VᵀV⟩_F` — two (c × c) Grams per row;
///   * centroid — rank-1 outer products accumulated into one buffer;
///   * `⟨U_n V_nᵀ, C⟩ = Σ_k u_kᵀ C v_k` — O(c·d1·d2) per row.
fn factored_stats(
    u: &crate::linalg::Mat,
    v: &crate::linalg::Mat,
    b: usize,
    d1: usize,
    d2: usize,
    c: usize,
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let d = d1 * d2;
    let mut norms2 = vec![0.0f64; b];
    for n in 0..b {
        let ur = u.row(n);
        let vr = v.row(n);
        let mut s = 0.0f64;
        for k in 0..c {
            for m in 0..c {
                let mut uu = 0.0f64;
                for a in 0..d1 {
                    uu += ur[a * c + k] as f64 * ur[a * c + m] as f64;
                }
                let mut vv = 0.0f64;
                for bb in 0..d2 {
                    vv += vr[bb * c + k] as f64 * vr[bb * c + m] as f64;
                }
                s += uu * vv;
            }
        }
        // the Frobenius identity is nonnegative in exact arithmetic;
        // clamp f64 round-off so sqrt never turns it into NaN.  NOT
        // `f64::max`, which would also swallow a NaN from genuinely
        // non-finite records — those must stay NaN so the summarizer
        // marks the chunk unprunable.
        norms2[n] = if s < 0.0 { 0.0 } else { s };
    }
    let mut cent = vec![0.0f64; d];
    for n in 0..b {
        let ur = u.row(n);
        let vr = v.row(n);
        for k in 0..c {
            for a in 0..d1 {
                let ua = ur[a * c + k] as f64;
                if ua != 0.0 {
                    let dst = &mut cent[a * d2..(a + 1) * d2];
                    for (bb, slot) in dst.iter_mut().enumerate() {
                        *slot += ua * vr[bb * c + k] as f64;
                    }
                }
            }
        }
    }
    for ci in cent.iter_mut() {
        *ci /= b as f64;
    }
    let mut dots = vec![0.0f64; b];
    for n in 0..b {
        let ur = u.row(n);
        let vr = v.row(n);
        let mut s = 0.0f64;
        for k in 0..c {
            for a in 0..d1 {
                let ua = ur[a * c + k] as f64;
                if ua != 0.0 {
                    let crow = &cent[a * d2..(a + 1) * d2];
                    let mut t = 0.0f64;
                    for (bb, &cv) in crow.iter().enumerate() {
                        t += cv * vr[bb * c + k] as f64;
                    }
                    s += ua * t;
                }
            }
        }
        dots[n] = s;
    }
    (norms2, cent, dots)
}

/// The whole sidecar: one summary per grid chunk, in stream order.
#[derive(Clone, Debug)]
pub struct StoreSummaries {
    /// grid stride in records (the last chunk of each shard may be
    /// shorter)
    pub chunk_size: usize,
    pub chunks: Vec<ChunkSummary>,
}

const MAGIC: &[u8; 8] = b"LORIFSM1";

impl StoreSummaries {
    /// Summary of the chunk starting at global example `start`.
    pub fn find(&self, start: usize) -> Option<&ChunkSummary> {
        self.chunks
            .binary_search_by(|c| c.start.cmp(&start))
            .ok()
            .map(|i| &self.chunks[i])
    }

    /// Validate against a store manifest: per-layer shapes match and
    /// the chunk grid exactly tiles every shard (restarting at each
    /// shard start), so a skip decision always covers whole records of
    /// one data file.
    pub fn validate(&self, meta: &StoreMeta) -> anyhow::Result<()> {
        anyhow::ensure!(self.chunk_size >= 1, "summary chunk size must be >= 1");
        for (i, ch) in self.chunks.iter().enumerate() {
            anyhow::ensure!(
                ch.layers.len() == meta.layers.len(),
                "summary chunk {i} has {} layers, store has {}",
                ch.layers.len(),
                meta.layers.len()
            );
            for (l, (ls, &(d1, d2))) in ch.layers.iter().zip(&meta.layers).enumerate() {
                anyhow::ensure!(
                    ls.centroid.len() == d1 * d2,
                    "summary chunk {i} layer {l}: centroid len {} != {}",
                    ls.centroid.len(),
                    d1 * d2
                );
            }
        }
        let shard_counts = meta.shards.clone().unwrap_or_else(|| vec![meta.n_examples]);
        let mut it = self.chunks.iter();
        let mut shard_start = 0usize;
        for (si, &sc) in shard_counts.iter().enumerate() {
            let mut pos = 0usize;
            while pos < sc {
                let want = self.chunk_size.min(sc - pos);
                let ch = it.next().ok_or_else(|| {
                    anyhow::anyhow!("summaries end early inside shard {si}")
                })?;
                anyhow::ensure!(
                    ch.start == shard_start + pos && ch.count == want,
                    "summary grid mismatch in shard {si}: chunk ({}, {}) where \
                     ({}, {want}) was expected",
                    ch.start,
                    ch.count,
                    shard_start + pos
                );
                pos += want;
            }
            shard_start += sc;
        }
        anyhow::ensure!(it.next().is_none(), "trailing summary chunks beyond the store");
        Ok(())
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.chunk_size as u32).to_le_bytes())?;
        let n_layers = self.chunks.first().map(|c| c.layers.len()).unwrap_or(0);
        f.write_all(&(n_layers as u32).to_le_bytes())?;
        f.write_all(&(self.chunks.len() as u32).to_le_bytes())?;
        for ch in &self.chunks {
            f.write_all(&(ch.start as u64).to_le_bytes())?;
            f.write_all(&(ch.count as u32).to_le_bytes())?;
            f.write_all(&ch.min_norm.to_le_bytes())?;
            f.write_all(&ch.max_norm.to_le_bytes())?;
            for ls in &ch.layers {
                f.write_all(&ls.max_row_norm.to_le_bytes())?;
                f.write_all(&ls.radius.to_le_bytes())?;
                f.write_all(&(ls.centroid.len() as u32).to_le_bytes())?;
                let mut buf = Vec::with_capacity(ls.centroid.len() * 4);
                for &x in &ls.centroid {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
                f.write_all(&buf)?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<StoreSummaries> {
        // every length field is corruption-controlled: bound it by the
        // actual file size BEFORE allocating, so a corrupt sidecar is a
        // clean error instead of a multi-GB allocation / OOM abort
        let file_len = std::fs::metadata(path)?.len() as usize;
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad summary sidecar magic");
        let chunk_size = read_u32(&mut f)? as usize;
        let n_layers = read_u32(&mut f)? as usize;
        let n_chunks = read_u32(&mut f)? as usize;
        // per chunk >= 20 B header, per layer >= 12 B header
        anyhow::ensure!(
            n_chunks
                .checked_mul(20 + 12 * n_layers)
                .map_or(false, |need| need <= file_len),
            "summary sidecar claims {n_chunks} chunks x {n_layers} layers \
             but holds only {file_len} B"
        );
        let mut chunks = Vec::with_capacity(n_chunks);
        for _ in 0..n_chunks {
            let mut b8 = [0u8; 8];
            f.read_exact(&mut b8)?;
            let start = u64::from_le_bytes(b8) as usize;
            let count = read_u32(&mut f)? as usize;
            let min_norm = read_f32(&mut f)?;
            let max_norm = read_f32(&mut f)?;
            let mut layers = Vec::with_capacity(n_layers);
            for _ in 0..n_layers {
                let max_row_norm = read_f32(&mut f)?;
                let radius = read_f32(&mut f)?;
                let len = read_u32(&mut f)? as usize;
                anyhow::ensure!(
                    len.checked_mul(4).map_or(false, |b| b <= file_len),
                    "summary sidecar centroid length {len} exceeds the file size"
                );
                let mut buf = vec![0u8; len * 4];
                f.read_exact(&mut buf)?;
                let centroid = buf
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                layers.push(LayerSummary { max_row_norm, radius, centroid });
            }
            let mut ch =
                ChunkSummary { start, count, min_norm, max_norm, finite: true, layers };
            ch.finite = ch.compute_finite();
            chunks.push(ch);
        }
        Ok(StoreSummaries { chunk_size, chunks })
    }
}

/// Writer-side builder: buffers the raw (already bf16-encoded) record
/// bytes of the open grid chunk and summarizes on every boundary.  The
/// sharded writer calls [`SummaryBuilder::flush`] when it rolls to a
/// new shard file, which is what restarts the grid per shard.
pub struct SummaryBuilder {
    meta: StoreMeta,
    chunk_size: usize,
    buf: Vec<u8>,
    buffered: usize,
    /// global index of the first buffered record
    start: usize,
    chunks: Vec<ChunkSummary>,
}

impl SummaryBuilder {
    pub fn new(meta: &StoreMeta, chunk_size: usize) -> SummaryBuilder {
        SummaryBuilder {
            meta: meta.clone(),
            chunk_size: chunk_size.max(1),
            buf: Vec::new(),
            buffered: 0,
            start: 0,
            chunks: Vec::new(),
        }
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    /// Account one encoded record (the writer's scratch bytes).
    pub fn add_record(&mut self, raw: &[u8]) -> anyhow::Result<()> {
        anyhow::ensure!(
            raw.len() == self.meta.bytes_per_example(),
            "record is {} B, store stride is {} B",
            raw.len(),
            self.meta.bytes_per_example()
        );
        self.buf.extend_from_slice(raw);
        self.buffered += 1;
        if self.buffered == self.chunk_size {
            self.flush()?;
        }
        Ok(())
    }

    /// Close the open grid chunk (no-op when empty).  Called at shard
    /// rolls and by [`SummaryBuilder::finish`].
    pub fn flush(&mut self) -> anyhow::Result<()> {
        if self.buffered == 0 {
            return Ok(());
        }
        let chunk = decode_chunk(&self.meta, self.start, &self.buf)?;
        self.chunks.push(summarize_chunk(&self.meta, &chunk)?);
        self.start += self.buffered;
        self.buffered = 0;
        self.buf.clear();
        Ok(())
    }

    pub fn finish(mut self) -> anyhow::Result<StoreSummaries> {
        self.flush()?;
        Ok(StoreSummaries { chunk_size: self.chunk_size, chunks: self.chunks })
    }
}

fn read_u32(f: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32(f: &mut impl Read) -> anyhow::Result<f32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::store::StoreKind;
    use crate::util::prng::Rng;

    fn dense_meta(layers: Vec<(usize, usize)>) -> StoreMeta {
        StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers,
            n_examples: 0,
            shards: None,
            summary_chunk: None,
        }
    }

    fn dense_chunk(g: Vec<Mat>, start: usize) -> Chunk {
        let count = g[0].rows;
        Chunk {
            start,
            count,
            layers: g.into_iter().map(|g| ChunkLayer::Dense { g }).collect(),
            io_time: std::time::Duration::ZERO,
        }
    }

    #[test]
    fn dense_summary_bounds_every_row() {
        let mut rng = Rng::new(3);
        let g = Mat::random_normal(9, 12, 1.0, &mut rng);
        let meta = dense_meta(vec![(3, 4)]);
        let s = summarize_chunk(&meta, &dense_chunk(vec![g.clone()], 5)).unwrap();
        assert_eq!(s.start, 5);
        assert_eq!(s.count, 9);
        assert!(s.finite);
        let ls = &s.layers[0];
        for n in 0..9 {
            let row = g.row(n);
            let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(norm <= ls.max_row_norm, "row {n}: {norm} > {}", ls.max_row_norm);
            let dist = row
                .iter()
                .zip(&ls.centroid)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            assert!(dist <= ls.radius, "row {n}: {dist} > {}", ls.radius);
            assert!(norm >= s.min_norm && norm <= s.max_norm);
        }
    }

    #[test]
    fn factored_norms_match_materialized_product() {
        use crate::curvature::reconstruct_row;
        let (d1, d2, c, b) = (5, 4, 2, 6);
        let mut rng = Rng::new(11);
        let u = Mat::random_normal(b, d1 * c, 1.0, &mut rng);
        let v = Mat::random_normal(b, d2 * c, 1.0, &mut rng);
        let meta = StoreMeta { c, kind: StoreKind::Factored, ..dense_meta(vec![(d1, d2)]) };
        let chunk = Chunk {
            start: 0,
            count: b,
            layers: vec![ChunkLayer::Factored { u: u.clone(), v: v.clone() }],
            io_time: std::time::Duration::ZERO,
        };
        let s = summarize_chunk(&meta, &chunk).unwrap();
        // reference: materialize every product
        let mut recs = Mat::zeros(b, d1 * d2);
        for n in 0..b {
            reconstruct_row(u.row(n), v.row(n), d1, d2, c, recs.row_mut(n));
        }
        let want_max = (0..b)
            .map(|n| recs.row(n).iter().map(|x| x * x).sum::<f32>().sqrt())
            .fold(0.0f32, f32::max);
        assert!((s.layers[0].max_row_norm - want_max).abs() < 1e-3 * want_max.max(1.0));
        // centroid equals the mean reconstruction; radius covers rows
        for n in 0..b {
            let dist = recs
                .row(n)
                .iter()
                .zip(&s.layers[0].centroid)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            assert!(dist <= s.layers[0].radius, "{dist} > {}", s.layers[0].radius);
        }
    }

    #[test]
    fn nan_rows_poison_the_chunk() {
        let mut rng = Rng::new(5);
        let mut g = Mat::random_normal(4, 6, 1.0, &mut rng);
        *g.at_mut(2, 3) = f32::NAN;
        let meta = dense_meta(vec![(2, 3)]);
        let s = summarize_chunk(&meta, &dense_chunk(vec![g], 0)).unwrap();
        assert!(!s.finite);
        assert_eq!(s.layers[0].max_row_norm, f32::INFINITY);
    }

    #[test]
    fn sidecar_roundtrip_and_validation() {
        let mut rng = Rng::new(7);
        let meta = StoreMeta { n_examples: 10, ..dense_meta(vec![(2, 3), (2, 2)]) };
        let mk = |start: usize, count: usize, rng: &mut Rng| {
            let g1 = Mat::random_normal(count, 6, 1.0, rng);
            let g2 = Mat::random_normal(count, 4, 1.0, rng);
            summarize_chunk(&meta, &dense_chunk(vec![g1, g2], start)).unwrap()
        };
        let sums = StoreSummaries {
            chunk_size: 4,
            chunks: vec![mk(0, 4, &mut rng), mk(4, 4, &mut rng), mk(8, 2, &mut rng)],
        };
        sums.validate(&meta).unwrap();
        let dir = std::env::temp_dir().join("lorif_sketch_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.summaries");
        sums.save(&path).unwrap();
        let back = StoreSummaries::load(&path).unwrap();
        assert_eq!(back.chunk_size, 4);
        assert_eq!(back.chunks.len(), 3);
        assert_eq!(back.chunks[1].start, 4);
        assert_eq!(back.chunks[2].count, 2);
        for (a, b) in sums.chunks.iter().zip(&back.chunks) {
            assert_eq!(a.min_norm, b.min_norm);
            assert_eq!(a.layers[0].centroid, b.layers[0].centroid);
            assert_eq!(a.layers[1].radius, b.layers[1].radius);
        }
        back.validate(&meta).unwrap();
        assert!(back.find(4).is_some());
        assert!(back.find(5).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn validation_rejects_grid_mismatch() {
        let mut rng = Rng::new(9);
        let meta = StoreMeta { n_examples: 8, ..dense_meta(vec![(2, 2)]) };
        let mk = |start: usize, count: usize, rng: &mut Rng| {
            let g = Mat::random_normal(count, 4, 1.0, rng);
            summarize_chunk(&meta, &dense_chunk(vec![g], start)).unwrap()
        };
        // wrong stride: chunks of 3 against a declared grid of 4
        let sums = StoreSummaries {
            chunk_size: 4,
            chunks: vec![mk(0, 3, &mut rng), mk(3, 5, &mut rng)],
        };
        assert!(sums.validate(&meta).is_err());
        // missing tail
        let sums = StoreSummaries { chunk_size: 4, chunks: vec![mk(0, 4, &mut rng)] };
        assert!(sums.validate(&meta).is_err());
        // sharded grid must restart at the shard boundary
        let meta2 = StoreMeta { shards: Some(vec![5, 3]), ..meta.clone() };
        let good = StoreSummaries {
            chunk_size: 4,
            chunks: vec![mk(0, 4, &mut rng), mk(4, 1, &mut rng), mk(5, 3, &mut rng)],
        };
        good.validate(&meta2).unwrap();
        let bad = StoreSummaries {
            chunk_size: 4,
            chunks: vec![mk(0, 4, &mut rng), mk(4, 4, &mut rng)],
        };
        assert!(bad.validate(&meta2).is_err());
    }

    #[test]
    fn builder_flushes_on_grid_and_shard_boundaries() {
        use crate::util::bf16;
        let meta = dense_meta(vec![(1, 3)]);
        let mut b = SummaryBuilder::new(&meta, 2);
        let mut push = |b: &mut SummaryBuilder, vals: [f32; 3]| {
            let mut raw = Vec::new();
            bf16::encode_slice(&vals, &mut raw);
            b.add_record(&raw).unwrap();
        };
        push(&mut b, [1.0, 0.0, 0.0]);
        push(&mut b, [0.0, 1.0, 0.0]);
        push(&mut b, [0.0, 0.0, 1.0]);
        b.flush().unwrap(); // simulated shard roll after a short chunk
        push(&mut b, [2.0, 0.0, 0.0]);
        let sums = b.finish().unwrap();
        assert_eq!(sums.chunks.len(), 3);
        assert_eq!(
            sums.chunks.iter().map(|c| (c.start, c.count)).collect::<Vec<_>>(),
            vec![(0, 2), (2, 1), (3, 1)]
        );
        // the singleton chunks have zero radius (row == centroid)
        assert!(sums.chunks[1].layers[0].radius < 1e-5);
        assert!((sums.chunks[2].layers[0].max_row_norm - 2.0).abs() < 1e-3);
    }
}

//! Shared harness for the paper-reproduction benches (`benches/`).
//!
//! Each bench binary regenerates one table or figure.  They share a work
//! directory, corpus, base model, and LDS retraining actuals (all keyed
//! by config and cached on disk), so the expensive steps are paid once
//! across the whole `cargo bench` run.
//!
//! Scale: defaults are sized for the single-core CPU testbed; set
//! `LORIF_SCALE=full` for larger corpora / more subsets (closer to the
//! paper's protocol, much slower).

use std::time::Duration;

use crate::config::Config;
use crate::query::LatencyBreakdown;

#[cfg(feature = "xla")]
use crate::app::{self, Method};
#[cfg(feature = "xla")]
use crate::attribution::{QueryGrads, Scorer};
#[cfg(feature = "xla")]
use crate::corpus::Dataset;
#[cfg(feature = "xla")]
use crate::eval::{LdsActuals, LdsProtocol, TailPatchProtocol};
#[cfg(feature = "xla")]
use crate::index::{Pipeline, Stage1Options};
#[cfg(feature = "xla")]
use crate::query::QueryEngine;

pub fn full_scale() -> bool {
    std::env::var("LORIF_SCALE").as_deref() == Ok("full")
}

/// Base bench configuration (small tier unless overridden).
pub fn bench_config() -> Config {
    let mut cfg = Config::default();
    if full_scale() {
        cfg.n_train = 8192;
        cfg.n_query = 96;
        cfg.train_steps = 600;
    } else {
        cfg.n_train = 1024;
        cfg.n_query = 32;
        cfg.train_steps = 250;
    }
    cfg.work_dir = "work/bench".into();
    cfg
}

#[cfg(feature = "xla")]
pub fn lds_protocol() -> LdsProtocol {
    let mut p = LdsProtocol::default();
    if full_scale() {
        p.n_subsets = 48;
        p.steps = 300;
    } else {
        p.n_subsets = 12;
        p.steps = 100;
    }
    p
}

#[cfg(feature = "xla")]
pub fn tailpatch_protocol() -> TailPatchProtocol {
    TailPatchProtocol { k: 8, lr: 1e-2 }
}

/// One measured configuration: everything the paper tables report.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub method: String,
    pub f: usize,
    pub c: usize,
    pub r: usize,
    pub lds: Option<(f64, f64)>,
    pub tail_patch: Option<(f64, f64)>,
    pub storage_bytes: u64,
    pub latency: Option<LatencyBreakdownLite>,
    pub stage1: Duration,
    pub stage2: Duration,
}

#[derive(Clone, Debug)]
pub struct LatencyBreakdownLite {
    pub load_s: f64,
    pub compute_s: f64,
    pub pre_s: f64,
}

impl From<&LatencyBreakdown> for LatencyBreakdownLite {
    fn from(l: &LatencyBreakdown) -> Self {
        LatencyBreakdownLite { load_s: l.load_s, compute_s: l.compute_s, pre_s: l.precondition_s }
    }
}

impl Measurement {
    pub fn latency_total(&self) -> f64 {
        self.latency.as_ref().map(|l| l.load_s + l.compute_s + l.pre_s).unwrap_or(0.0)
    }

    pub fn storage_mb(&self) -> f64 {
        self.storage_bytes as f64 / 1e6
    }
}

/// Bench session: shared pipeline state across configurations.
#[cfg(feature = "xla")]
pub struct Session {
    base_cfg: Config,
}

#[cfg(feature = "xla")]
impl Session {
    pub fn new() -> Session {
        crate::util::logging::init();
        Session { base_cfg: bench_config() }
    }

    pub fn with_tier(tier: crate::model::spec::Tier) -> Session {
        crate::util::logging::init();
        let mut cfg = bench_config();
        cfg.tier = tier;
        // larger tiers: smaller corpus (CPU budget)
        if tier != crate::model::spec::Tier::Small {
            cfg.n_train = cfg.n_train / 2;
        }
        Session { base_cfg: cfg }
    }

    pub fn config(&self, f: usize, c: usize, r: usize) -> Config {
        let mut cfg = self.base_cfg.clone();
        cfg.f = f;
        cfg.c = c;
        cfg.r = r;
        cfg
    }

    /// Run one (method, f, c, r) configuration end-to-end and measure.
    pub fn measure(
        &self,
        method: Method,
        f: usize,
        c: usize,
        r: usize,
        want_lds: bool,
        want_tailpatch: bool,
    ) -> anyhow::Result<Measurement> {
        let cfg = self.config(f, c, r);
        let p = Pipeline::new(cfg)?;
        let (train, queries) = p.corpus()?;
        let params = p.base_params(&train)?;
        let lit = p.params_literal(&params)?;
        let s1 = p.stage1(
            &lit,
            &train,
            Stage1Options {
                write_factored: true,
                write_dense: method.needs_dense_store()
                    || matches!(method, Method::RepSim | Method::Ekfac),
                write_embeddings: true,
            },
        )?;

        let mut stage2 = Duration::ZERO;
        let qg = p.query_grads(&lit, &queries)?;
        // the tables correlate against every score (LDS), so the bench
        // engine always runs the full-matrix sink
        let (scores, latency, storage) = match method {
            Method::RepSim => {
                let scorer = app::build_repsim_scorer(&p, &lit, &queries)?;
                let bytes = scorer.index_bytes();
                let mut e = QueryEngine::new(scorer, 10);
                e.topk_threads = p.cfg.score_threads;
                let res = e.run(&qg)?;
                (res.scores.expect("full sink"), res.latency, bytes)
            }
            Method::Ekfac => {
                let extractor =
                    crate::runtime::GradExtractor::new(&p.rt, p.cfg.tier, 1, 1)?;
                let qg1 = QueryGrads::extract(&p.rt, &extractor, &lit, &queries)?;
                let t0 = std::time::Instant::now();
                let scorer = app::build_ekfac_scorer(&p, &extractor, &lit, &train, 256)?;
                stage2 = t0.elapsed();
                let bytes = scorer.index_bytes();
                let mut e = QueryEngine::new(scorer, 10);
                e.topk_threads = p.cfg.score_threads;
                let res = e.run(&qg1)?;
                (res.scores.expect("full sink"), res.latency, bytes)
            }
            _ => {
                let t0 = std::time::Instant::now();
                let scorer = app::build_store_scorer(&p, method)?;
                stage2 = t0.elapsed();
                let bytes = scorer.index_bytes();
                let mut e = QueryEngine::new(scorer, 10);
                e.topk_threads = p.cfg.score_threads;
                let res = e.run(&qg)?;
                (res.scores.expect("full sink"), res.latency, bytes)
            }
        };

        let lds = if want_lds {
            let actuals = LdsActuals::get(&p, &lds_protocol(), &train, &queries)?;
            Some(actuals.lds(&scores))
        } else {
            None
        };
        let tail_patch = if want_tailpatch {
            let proto = tailpatch_protocol();
            // same total_cmp order as ScoreReport::topk, without cloning
            // the full (Nq, N) matrix into a throwaway report
            let topk = crate::query::parallel::topk(&scores, proto.k, p.cfg.score_threads);
            let tp = crate::eval::tail_patch(&p, &params, &train, &queries, &topk, proto)?;
            Some(crate::eval::tail_patch_mean(&tp))
        } else {
            None
        };

        Ok(Measurement {
            method: method.name().to_string(),
            f,
            c,
            r,
            lds,
            tail_patch,
            storage_bytes: storage,
            latency: Some(LatencyBreakdownLite::from(&latency)),
            stage1: s1.wall,
            stage2,
        })
    }

    /// Access to the underlying pieces for custom benches.
    pub fn pipeline(&self, f: usize, c: usize, r: usize) -> anyhow::Result<Pipeline> {
        Pipeline::new(self.config(f, c, r))
    }

    pub fn prepared(
        &self,
        f: usize,
        c: usize,
        r: usize,
    ) -> anyhow::Result<(Pipeline, Dataset, Dataset, Vec<f32>)> {
        let p = self.pipeline(f, c, r)?;
        let (train, queries) = p.corpus()?;
        let params = p.base_params(&train)?;
        Ok((p, train, queries, params))
    }
}

// ---------------------------------------------------------------------------
// table printer
// ---------------------------------------------------------------------------

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> =
                cells.iter().enumerate().map(|(i, c)| format!("{:>w$}", c, w = widths[i])).collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }

    /// Also persist as JSON under work/bench/results/.
    pub fn save(&self, name: &str) -> anyhow::Result<()> {
        let dir = std::path::PathBuf::from("work/bench/results");
        std::fs::create_dir_all(&dir)?;
        let rows: Vec<crate::util::json::Value> = self
            .rows
            .iter()
            .map(|r| {
                crate::util::json::Value::Obj(
                    self.headers
                        .iter()
                        .zip(r)
                        .map(|(h, c)| (h.clone(), crate::util::json::Value::Str(c.clone())))
                        .collect(),
                )
            })
            .collect();
        let doc = crate::util::json::obj([
            ("title", self.title.as_str().into()),
            ("rows", crate::util::json::Value::Arr(rows)),
        ]);
        std::fs::write(dir.join(format!("{name}.json")), doc.to_string())?;
        Ok(())
    }
}

pub fn fmt_pm(v: Option<(f64, f64)>) -> String {
    match v {
        Some((m, ci)) => format!("{m:.4} ± {ci:.4}"),
        None => "—".into(),
    }
}

pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.1} MB", bytes as f64 / 1e6)
}

pub fn fmt_s(secs: f64) -> String {
    if secs < 0.1 {
        format!("{:.1} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

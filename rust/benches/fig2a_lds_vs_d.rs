//! Figure 2a: attribution quality (LDS) vs effective projection
//! dimension D, LoGRA (no factorization) vs rank-c factorization.
//!
//! Paper setup: GPT2-small/WikiText-103, f in {64,32,16,8} so D = IO/f^2,
//! c varied at fixed f.  Scaled here to the small tier with
//! f in {16,8,4,2} and c in {1,2,4,8} at f=2.
//! Expected shape: LDS rises with D for both; LoRIF-c1 tracks LoGRA from
//! below at each D (factorization costs some quality at fixed D) and
//! larger c closes the gap.

use lorif::app::Method;
use lorif::bench_support::{fmt_pm, Session, Table};

fn main() -> anyhow::Result<()> {
    let s = Session::new();
    let mut table = Table::new(
        "Fig 2a: LDS vs effective projection dimension D (small tier)",
        &["method", "f", "c", "D", "LDS"],
    );
    let spec = lorif::model::spec::Tier::Small.spec();

    for f in [16, 8, 4, 2] {
        let m = s.measure(Method::Logra, f, 1, 64, true, false)?;
        table.row(vec![
            "LoGRA".into(),
            f.to_string(),
            "—".into(),
            spec.total_proj_dim(f).to_string(),
            fmt_pm(m.lds),
        ]);
    }
    // rank-1 factorization across D; r scales with D
    for (f, r) in [(16, 32), (8, 64), (4, 128), (2, 256)] {
        let m = s.measure(Method::Lorif, f, 1, r, true, false)?;
        table.row(vec![
            "LoRIF".into(),
            f.to_string(),
            "1".into(),
            spec.total_proj_dim(f).to_string(),
            fmt_pm(m.lds),
        ]);
    }
    // higher c at the largest D
    for c in [2, 4] {
        let m = s.measure(Method::Lorif, 2, c, 256, true, false)?;
        table.row(vec![
            "LoRIF".into(),
            "2".into(),
            c.to_string(),
            spec.total_proj_dim(2).to_string(),
            fmt_pm(m.lds),
        ]);
    }
    table.print();
    table.save("fig2a")?;
    Ok(())
}

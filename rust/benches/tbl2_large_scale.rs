//! Table 2: large-scale attribution — tail-patch score on the medium
//! (OLMo-3-7B stand-in) and large (Apertus-70B stand-in) tiers, where
//! repeated subset retraining for LDS would be infeasible.
//!
//! Expected shape (per tier): RepSim cheapest but lowest tail-patch;
//! LoRIF at matched f ~matches LoGRA with far less storage/latency; LoRIF
//! at smaller f (larger D) wins outright while still using less storage.

use lorif::app::Method;
use lorif::bench_support::{fmt_mb, fmt_pm, fmt_s, Session, Table};
use lorif::model::spec::Tier;

fn main() -> anyhow::Result<()> {
    for tier in [Tier::Medium, Tier::Large] {
        let s = Session::with_tier(tier);
        let mut table = Table::new(
            &format!("Table 2: tail-patch comparison ({} tier)", tier.name()),
            &["method", "f", "c", "r", "tail-patch", "storage", "latency"],
        );
        let mut add = |m: lorif::bench_support::Measurement| {
            let c = if m.method == "lorif" { m.c.to_string() } else { "—".into() };
            let r = if m.method == "lorif" { m.r.to_string() } else { "—".into() };
            table.row(vec![
                m.method.clone(),
                m.f.to_string(),
                c,
                r,
                fmt_pm(m.tail_patch),
                fmt_mb(m.storage_bytes),
                fmt_s(m.latency_total()),
            ]);
        };
        // artifact grid: medium has f {4,8,16}, large has f {8,16}
        let (f_base, f_big_d) = match tier {
            Tier::Medium => (8, 4),
            _ => (16, 8),
        };
        add(s.measure(Method::RepSim, f_base, 1, 64, false, true)?);
        add(s.measure(Method::GradDot, f_base, 1, 64, false, true)?);
        add(s.measure(Method::Logra, f_base, 1, 64, false, true)?);
        add(s.measure(Method::Lorif, f_base, 1, 64, false, true)?);
        add(s.measure(Method::Lorif, f_big_d, 1, 128, false, true)?);
        table.print();
        table.save(&format!("tbl2_{}", tier.name()))?;
    }
    Ok(())
}

//! Tables 3 / 12 / 13: top-1 retrieval evaluation with the programmatic
//! relevance judge (Claude-Haiku stand-in; see eval::judge).
//!
//! LoRIF uses a smaller f (larger effective D, possible because the
//! factored store stays small) vs LoGRA at its storage-feasible f —
//! matching the paper's evaluated configurations (LoRIF f=16 vs LoGRA
//! f=128 on OLMo).  Expected shape: LoRIF higher average relevance,
//! much lower score-1 rate, and most non-tied comparisons won.

use lorif::app::{build_store_scorer, Method};
use lorif::attribution::Scorer;
use lorif::bench_support::{Session, Table};
use lorif::eval::judge;
use lorif::index::Stage1Options;
use lorif::model::spec::Tier;

fn main() -> anyhow::Result<()> {
    for tier in [Tier::Medium, Tier::Large] {
        let s = Session::with_tier(tier);
        // LoRIF at larger D (smaller f), LoGRA at the storage-limited f
        let (f_logra, f_lorif) = match tier {
            Tier::Medium => (8, 4),
            _ => (16, 8),
        };
        // LoGRA pipeline at its f
        let (p_logra, train, queries, params) = s.prepared(f_logra, 1, 64)?;
        let lit = p_logra.params_literal(&params)?;
        p_logra.stage1(&lit, &train, Stage1Options::default())?;
        let qg_logra = p_logra.query_grads(&lit, &queries)?;
        let mut logra = build_store_scorer(&p_logra, Method::Logra)?;
        let top_logra: Vec<usize> =
            logra.score(&qg_logra)?.topk(1).iter().map(|t| t[0]).collect();

        // LoRIF pipeline at its (smaller) f
        let (p_lorif, _, _, _) = s.prepared(f_lorif, 1, 128)?;
        p_lorif.stage1(&lit, &train, Stage1Options { write_dense: false, ..Default::default() })?;
        let qg_lorif = p_lorif.query_grads(&lit, &queries)?;
        let mut lorif = build_store_scorer(&p_lorif, Method::Lorif)?;
        let top_lorif: Vec<usize> =
            lorif.score(&qg_lorif)?.topk(1).iter().map(|t| t[0]).collect();

        let tm = p_logra.topic_model();
        let sa = judge::judge_top1(&tm, &queries, &train, &top_lorif);
        let sb = judge::judge_top1(&tm, &queries, &train, &top_logra);
        let (aw, bw, tie) = judge::preference(&tm, &queries, &train, &top_lorif, &top_logra);

        let mut t3 = Table::new(
            &format!("Table 3/12: top-1 relevance ({} tier)", tier.name()),
            &["metric", "LoRIF", "LoGRA"],
        );
        t3.row(vec![
            format!("config"),
            format!("f={f_lorif} c=1"),
            format!("f={f_logra}"),
        ]);
        t3.row(vec![
            "avg relevance".into(),
            format!("{:.2}", sa.avg_score),
            format!("{:.2}", sb.avg_score),
        ]);
        t3.row(vec![
            "score-1 rate".into(),
            format!("{:.1}%", 100.0 * sa.score1_rate),
            format!("{:.1}%", 100.0 * sb.score1_rate),
        ]);
        t3.row(vec![
            "score>=4 rate".into(),
            format!("{:.1}%", 100.0 * sa.score_ge4_rate),
            format!("{:.1}%", 100.0 * sb.score_ge4_rate),
        ]);
        t3.row(vec![
            "preference".into(),
            format!("{:.1}%", 100.0 * aw),
            format!("{:.1}% (tie {:.1}%)", 100.0 * bw, 100.0 * tie),
        ]);
        t3.print();
        t3.save(&format!("tbl3_{}", tier.name()))?;

        let mut t13 = Table::new(
            &format!("Table 13: relevance distribution ({} tier)", tier.name()),
            &["score", "meaning", "LoRIF", "LoGRA"],
        );
        let meanings =
            ["completely irrelevant", "vaguely related", "same broad topic", "closely related", "nearly identical"];
        for i in 0..5 {
            t13.row(vec![
                (i + 1).to_string(),
                meanings[i].into(),
                format!("{:.1}%", 100.0 * sa.dist[i]),
                format!("{:.1}%", 100.0 * sb.dist[i]),
            ]);
        }
        t13.print();
        t13.save(&format!("tbl13_{}", tier.name()))?;
    }
    Ok(())
}

//! §Perf microbenchmarks: the L3 hot-path primitives in isolation.
//!
//! Measures: bf16 decode throughput, blocked GEMM GFLOP/s, factor-dot
//! scoring throughput, reconstruct+project throughput, store streaming
//! bandwidth (sync vs prefetch), sharded multi-threaded scoring vs the
//! single-reader monolithic path, full-matrix vs streaming-top-k score
//! sinks (latency + peak score memory), the quantized-domain scoring
//! roofline (per-kernel on-disk GB/s, `--quant-score on` vs
//! decode-then-score, per int codec), the clustered retrieval tier
//! (best-first scan over a `--cluster`-reordered store: exact and
//! `recall=x` bytes/latency/overlap vs the unclustered full scan),
//! and (with `--features xla`) the XLA-executable scorer vs the
//! Rust-native scorer.  The before/after log lives in EXPERIMENTS.md
//! §Perf.
//!
//! `LORIF_PERF_QUICK=1` shrinks sizes and iteration counts for the CI
//! perf-smoke job; the sink comparison is also persisted as JSON to
//! `work/bench/results/perf_smoke.json` so the memory/latency win is
//! tracked per PR.

use std::time::Instant;

use lorif::attribution::lorif::factor_dots;
use lorif::linalg::Mat;
use lorif::util::bf16;
use lorif::util::prng::Rng;

fn quick() -> bool {
    std::env::var("LORIF_PERF_QUICK").as_deref() == Ok("1")
}

fn time<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let iters = if quick() { (iters / 2).max(1) } else { iters };
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(1);
    println!("=== §Perf microbenchmarks (1 iteration values) ===");

    // bf16 decode
    {
        let n = 1 << 20;
        let src: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut bytes = Vec::new();
        bf16::encode_slice(&src, &mut bytes);
        let mut dst = vec![0.0f32; n];
        let t = time(20, || bf16::decode_into(&bytes, &mut dst));
        println!(
            "bf16 decode: {:.2} GB/s out ({:.3} ms / 4 MiB)",
            (n * 4) as f64 / t / 1e9,
            t * 1e3
        );
    }

    // GEMM
    for (m, k, n) in [(512, 768, 48), (2048, 768, 128), (512, 512, 512)] {
        let a = Mat::random_normal(m, k, 1.0, &mut rng);
        let b = Mat::random_normal(k, n, 1.0, &mut rng);
        let t = time(5, || {
            let _ = a.matmul(&b);
        });
        println!(
            "gemm {m}x{k}x{n}: {:.2} GFLOP/s ({:.2} ms)",
            2.0 * (m * k * n) as f64 / t / 1e9,
            t * 1e3
        );
    }

    // factor dots (c = 1 fast path): B x Nq pairings
    {
        let (b, nq, d1, d2) = (2048, 48, 16, 48);
        let u = Mat::random_normal(b, d1, 1.0, &mut rng);
        let v = Mat::random_normal(b, d2, 1.0, &mut rng);
        let uq = Mat::random_normal(nq, d1, 1.0, &mut rng);
        let vq = Mat::random_normal(nq, d2, 1.0, &mut rng);
        let t = time(10, || {
            let _ = factor_dots(&u, &v, &uq, &vq, d1, d2, 1);
        });
        println!(
            "factor-dot c=1 ({b}x{nq} pairs): {:.1} Mpairs/s ({:.2} ms)",
            (b * nq) as f64 / t / 1e6,
            t * 1e3
        );
    }

    // reconstruct + project (the faithful Woodbury path)
    {
        let (b, d1, d2, r) = (512, 16, 48, 128);
        let u = Mat::random_normal(b, d1, 1.0, &mut rng);
        let v = Mat::random_normal(b, d2, 1.0, &mut rng);
        let vr = Mat::random_normal(d1 * d2, r, 1.0, &mut rng);
        let mut scratch = Mat::zeros(b, d1 * d2);
        let t = time(5, || {
            for ex in 0..b {
                lorif::curvature::reconstruct_row(
                    u.row(ex), v.row(ex), d1, d2, 1, scratch.row_mut(ex),
                );
            }
            let _ = scratch.matmul(&vr);
        });
        println!(
            "reconstruct+project B={b} D={} r={r}: {:.1} ex/ms ({:.2} ms)",
            d1 * d2,
            b as f64 / (t * 1e3),
            t * 1e3
        );
    }

    // store streaming: sync vs prefetch
    {
        use lorif::runtime::{ExtractBatch, LayerGrads};
        use lorif::store::{StoreKind, StoreMeta, StoreReader, StoreWriter};
        let dir = std::env::temp_dir().join("lorif_perf_store");
        std::fs::create_dir_all(&dir)?;
        let base = dir.join("perf");
        let layers = vec![(16usize, 48usize), (16, 16), (16, 32), (32, 16)];
        let n = 4096;
        if !StoreMeta::meta_path(&base).exists() {
            let meta = StoreMeta {
                kind: StoreKind::Dense,
                tier: "small".into(),
                f: 4,
                c: 1,
                layers: layers.clone(),
                n_examples: 0,
                shards: None,
                summary_chunk: None,
                codec: lorif::store::CodecId::Bf16,
            };
            let mut w = StoreWriter::create(&base, meta)?;
            let lg: Vec<LayerGrads> = layers
                .iter()
                .map(|&(d1, d2)| LayerGrads {
                    g: Mat::random_normal(n, d1 * d2, 1.0, &mut rng),
                    u: Mat::zeros(n, d1),
                    v: Mat::zeros(n, d2),
                })
                .collect();
            w.append(&ExtractBatch { losses: vec![0.0; n], layers: lg, valid: n })?;
            w.finalize()?;
        }
        let reader = StoreReader::open(&base)?;
        for prefetch in [false, true] {
            let t = time(3, || {
                let mut acc = 0.0f32;
                reader
                    .stream(512, prefetch, |chunk| {
                        acc += chunk.layers[0].dense().data[0];
                        Ok(())
                    })
                    .unwrap();
                std::hint::black_box(acc);
            });
            println!(
                "store stream (prefetch={prefetch}): {:.2} GB/s ({:.1} ms / {:.1} MB)",
                reader.meta.total_bytes() as f64 / t / 1e9,
                t * 1e3,
                reader.meta.total_bytes() as f64 / 1e6
            );
        }
    }

    // sharded multi-threaded scoring vs the single-reader monolithic path
    // (GradDot over identical dense records; Fig 3's I/O-bound pass),
    // plus the full-matrix vs streaming-top-k sink comparison
    {
        use lorif::attribution::graddot::GradDotScorer;
        use lorif::attribution::{QueryGrads, QueryLayer, Scorer, SinkSpec};
        use lorif::runtime::{ExtractBatch, LayerGrads};
        use lorif::store::{ShardSet, ShardedWriter, StoreKind, StoreMeta, StoreWriter};

        let dir = std::env::temp_dir().join("lorif_perf_sharded");
        std::fs::create_dir_all(&dir)?;
        let layers = vec![(16usize, 48usize), (16, 16), (16, 32), (32, 16)];
        let (n, nq) = (if quick() { 1024usize } else { 4096 }, 32usize);
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let shards = cores.clamp(2, 8);

        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: layers.clone(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: lorif::store::CodecId::Bf16,
        };
        let lg: Vec<LayerGrads> = layers
            .iter()
            .map(|&(d1, d2)| LayerGrads {
                g: Mat::random_normal(n, d1 * d2, 1.0, &mut rng),
                u: Mat::zeros(n, d1),
                v: Mat::zeros(n, d2),
            })
            .collect();
        let batch = ExtractBatch { losses: vec![0.0; n], layers: lg, valid: n };

        let mono_base = dir.join("mono");
        let mut w = StoreWriter::create(&mono_base, meta.clone())?;
        w.append(&batch)?;
        w.finalize()?;
        let shard_base = dir.join("sharded");
        let mut w = ShardedWriter::create(&shard_base, meta, shards, n)?;
        w.append(&batch)?;
        w.finalize()?;

        let qlayers: Vec<QueryLayer> = layers
            .iter()
            .map(|&(d1, d2)| QueryLayer {
                g: Mat::random_normal(nq, d1 * d2, 1.0, &mut rng),
                u: Mat::zeros(nq, d1),
                v: Mat::zeros(nq, d2),
            })
            .collect();
        let qg = QueryGrads {
            n_query: nq,
            c: 1,
            proj_dims: layers.clone(),
            layers: qlayers,
        };

        let mut mono = GradDotScorer::new(ShardSet::open(&mono_base)?);
        mono.score_threads = 1;
        let mut sharded = GradDotScorer::new(ShardSet::open(&shard_base)?);
        sharded.score_threads = 0; // all cores

        // correctness first: identical records must score identically
        let ra = mono.score(&qg)?;
        let rb = sharded.score(&qg)?;
        let scale = ra.scores().data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in ra.scores().data.iter().zip(&rb.scores().data) {
            assert!((a - b).abs() <= 1e-4 * scale.max(1.0), "{a} vs {b}");
        }

        let t_mono = time(3, || {
            let _ = mono.score(&qg).unwrap();
        });
        let t_shard = time(3, || {
            let _ = sharded.score(&qg).unwrap();
        });
        println!(
            "graddot scoring {n}x{nq}: monolithic 1-thread {:.1} ms | {shards} shards \
             on {cores} cores {:.1} ms | speedup {:.2}x",
            t_mono * 1e3,
            t_shard * 1e3,
            t_mono / t_shard
        );

        // full-matrix vs streaming-top-k sink: same kernel, same store;
        // the streaming path must hold <= Nq*k*shards score elements
        // while the full path materializes Nq*N
        let k = 10usize;
        let r_full = sharded.score_sink(&qg, SinkSpec::Full)?;
        let r_topk = sharded.score_sink(&qg, SinkSpec::TopK(k))?;
        assert_eq!(r_topk.topk(k), r_full.topk(k), "sink results diverged");
        assert!(r_topk.peak_sink_elems <= nq * k * shards);
        let t_full = time(3, || {
            let _ = sharded.score_sink(&qg, SinkSpec::Full).unwrap();
        });
        let t_topk = time(3, || {
            let _ = sharded.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
        });
        println!(
            "score sinks {n}x{nq} (k={k}): full {:.1} ms / {} elems | streaming top-k \
             {:.1} ms / {} elems ({:.0}x less score memory)",
            t_full * 1e3,
            r_full.peak_sink_elems,
            t_topk * 1e3,
            r_topk.peak_sink_elems,
            r_full.peak_sink_elems as f64 / r_topk.peak_sink_elems.max(1) as f64
        );

        // decoded-chunk cache: cold (first pass populates) vs warm
        // (every chunk served from residency) query over the same
        // sharded store — the serving-path win where repeated batches
        // hit the same hot spans.  Warm scoring must be bit-identical.
        let (t_cache_cold, t_cache_warm, warm_hits) = {
            use lorif::store::ChunkCache;
            let mut set = ShardSet::open(&shard_base)?;
            set.set_cache(Some(ChunkCache::with_capacity(256 << 20)));
            let mut cached = GradDotScorer::new(set);
            cached.score_threads = 0;
            let t_cold = {
                let t0 = std::time::Instant::now();
                let r = cached.score(&qg)?;
                let dt = t0.elapsed().as_secs_f64();
                assert_eq!(r.cache_hits, 0, "first pass must be cold");
                assert_eq!(r.scores().data, rb.scores().data, "cold cached pass diverged");
                dt
            };
            let r_warm = cached.score(&qg)?;
            assert_eq!(
                r_warm.scores().data,
                rb.scores().data,
                "cache-served scoring diverged from disk scoring"
            );
            assert_eq!(r_warm.bytes_from_cache, r_warm.bytes_read, "warm pass hit disk");
            let t_warm = time(3, || {
                let _ = cached.score(&qg).unwrap();
            });
            (t_cold, t_warm, r_warm.cache_hits)
        };
        println!(
            "chunk cache {n}x{nq}: cold {:.1} ms | warm {:.1} ms ({} chunk hits) | \
             speedup {:.2}x",
            t_cache_cold * 1e3,
            t_cache_warm * 1e3,
            warm_hits,
            t_cache_cold / t_cache_warm.max(1e-9)
        );

        // chunk pruning: bytes-skipped vs k on a clustered store (the
        // I/O half of the win; the sinks above are the memory half).
        // One strong query-aligned chunk, the rest weak — the shape the
        // summary index is built for.
        use lorif::sketch::PruneMode;
        let prune_base = dir.join("clustered");
        let grid = 512usize;
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: layers.clone(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: lorif::store::CodecId::Bf16,
        };
        let mut w = StoreWriter::create(&prune_base, meta)?;
        w.set_summary_chunk(grid)?;
        {
            let lg: Vec<LayerGrads> = layers
                .iter()
                .map(|&(d1, d2)| {
                    let mut g = Mat::zeros(n, d1 * d2);
                    for t in 0..n {
                        let scale = if t < grid { 4.0 } else { 0.02 };
                        for x in g.row_mut(t) {
                            *x = scale * (1.0 + 0.1 * rng.normal() as f32);
                        }
                    }
                    LayerGrads { g, u: Mat::zeros(n, d1), v: Mat::zeros(n, d2) }
                })
                .collect();
            w.append(&ExtractBatch { losses: vec![0.0; n], layers: lg, valid: n })?;
            w.finalize()?;
        }
        let aligned: Vec<QueryLayer> = layers
            .iter()
            .map(|&(d1, d2)| QueryLayer {
                g: Mat::from_vec(nq, d1 * d2, vec![1.0; nq * d1 * d2]),
                u: Mat::zeros(nq, d1),
                v: Mat::zeros(nq, d2),
            })
            .collect();
        let qa = QueryGrads { n_query: nq, c: 1, proj_dims: layers.clone(), layers: aligned };
        let mut pruned_scorer = GradDotScorer::new(ShardSet::open(&prune_base)?);
        pruned_scorer.score_threads = 1;
        let mut bytes_by_k = Vec::new();
        for kk in [1usize, 10, 100] {
            pruned_scorer.prune = PruneMode::Exact;
            let rp = pruned_scorer.score_sink(&qa, SinkSpec::TopK(kk))?;
            pruned_scorer.prune = PruneMode::Off;
            let rf = pruned_scorer.score_sink(&qa, SinkSpec::TopK(kk))?;
            assert_eq!(rp.topk(kk), rf.topk(kk), "exact pruning diverged (k={kk})");
            println!(
                "chunk pruning k={kk}: full scan {} B | pruned reads {} B, skips {} B \
                 ({} of {} chunks) -> {:.1}% of I/O avoided",
                rf.bytes_read,
                rp.bytes_read,
                rp.bytes_skipped,
                rp.chunks_skipped,
                (n + grid - 1) / grid,
                100.0 * rp.bytes_skipped as f64 / rf.bytes_read.max(1) as f64
            );
            if kk == 10 {
                bytes_by_k.push(("full_scan_bytes", (rf.bytes_read as usize).into()));
                bytes_by_k.push(("pruned_bytes_read", (rp.bytes_read as usize).into()));
                bytes_by_k.push(("pruned_bytes_skipped", (rp.bytes_skipped as usize).into()));
            }
        }
        let t_noprune = time(3, || {
            pruned_scorer.prune = PruneMode::Off;
            let _ = pruned_scorer.score_sink(&qa, SinkSpec::TopK(k)).unwrap();
        });
        let t_prune = time(3, || {
            pruned_scorer.prune = PruneMode::Exact;
            let _ = pruned_scorer.score_sink(&qa, SinkSpec::TopK(k)).unwrap();
        });
        println!(
            "pruned top-k (k={k}): full scan {:.1} ms | pruned {:.1} ms | speedup {:.2}x",
            t_noprune * 1e3,
            t_prune * 1e3,
            t_noprune / t_prune
        );

        // store codecs: the same sharded corpus recoded under every
        // codec — on-disk bytes (and the shrink vs bf16), streaming
        // decode throughput, end-to-end pruned top-k latency, per-codec
        // pruned ≡ full-scan exactness, and top-k overlap@k against the
        // bf16 reference.  All persisted to perf_smoke.json.
        let mut codec_fields: Vec<(&'static str, lorif::util::json::Value)> = Vec::new();
        {
            use lorif::sketch::PruneMode as CodecPrune;
            use lorif::store::{recode_store, CodecId, RecodeOptions};
            let mut ref_topk: Option<Vec<Vec<usize>>> = None;
            let mut bf16_bytes = 0u64;
            for codec in CodecId::ALL {
                let base = if codec == CodecId::Bf16 {
                    shard_base.clone()
                } else {
                    let dst = dir.join(format!("codec_{}", codec.as_str()));
                    recode_store(
                        &shard_base,
                        &dst,
                        &RecodeOptions { codec: Some(codec), ..Default::default() },
                    )?;
                    dst
                };
                let set = ShardSet::open(&base)?;
                let disk_bytes = set.meta.total_bytes();
                let t_decode = time(3, || {
                    let mut acc = 0.0f32;
                    set.stream(512, false, |chunk| {
                        acc += chunk.layers[0].dense().data[0];
                        Ok(())
                    })
                    .unwrap();
                    std::hint::black_box(acc);
                });
                let mut scorer = GradDotScorer::new(ShardSet::open(&base)?);
                scorer.score_threads = 0;
                scorer.prune = CodecPrune::Exact;
                let pruned = scorer.score_sink(&qg, SinkSpec::TopK(k))?;
                scorer.prune = CodecPrune::Off;
                let full = scorer.score_sink(&qg, SinkSpec::TopK(k))?;
                assert_eq!(
                    pruned.topk(k),
                    full.topk(k),
                    "codec {}: pruned top-k diverged from its own full scan",
                    codec.as_str()
                );
                scorer.prune = CodecPrune::Exact;
                let t_topk = time(3, || {
                    let _ = scorer.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
                });
                let topk = full.topk(k);
                let overlap = match &ref_topk {
                    None => {
                        bf16_bytes = disk_bytes;
                        ref_topk = Some(topk);
                        1.0
                    }
                    Some(reference) => {
                        let inter: usize = reference
                            .iter()
                            .zip(&topk)
                            .map(|(a, b)| a.iter().filter(|i| b.contains(i)).count())
                            .sum();
                        inter as f64 / (nq * k) as f64
                    }
                };
                println!(
                    "codec {}: {:.2} MB on disk ({:.2}x smaller than bf16) | decode \
                     {:.2} GB/s ({:.1} ms) | pruned top-k {:.1} ms | overlap@{k} {:.3}",
                    codec.as_str(),
                    disk_bytes as f64 / 1e6,
                    bf16_bytes as f64 / disk_bytes.max(1) as f64,
                    disk_bytes as f64 / t_decode / 1e9,
                    t_decode * 1e3,
                    t_topk * 1e3,
                    overlap
                );
                let (f_bytes, f_dec, f_topk, f_overlap) = match codec {
                    CodecId::Bf16 => (
                        "codec_bf16_bytes",
                        "codec_bf16_decode_ms",
                        "codec_bf16_topk_ms",
                        "codec_bf16_overlap_at_k",
                    ),
                    CodecId::Int8 => (
                        "codec_int8_bytes",
                        "codec_int8_decode_ms",
                        "codec_int8_topk_ms",
                        "codec_int8_overlap_at_k",
                    ),
                    CodecId::Int4 => (
                        "codec_int4_bytes",
                        "codec_int4_decode_ms",
                        "codec_int4_topk_ms",
                        "codec_int4_overlap_at_k",
                    ),
                };
                codec_fields.push((f_bytes, (disk_bytes as usize).into()));
                codec_fields.push((f_dec, (t_decode * 1e3).into()));
                codec_fields.push((f_topk, (t_topk * 1e3).into()));
                codec_fields.push((f_overlap, overlap.into()));
                if codec == CodecId::Int8 {
                    codec_fields.push((
                        "codec_int8_shrink_vs_bf16",
                        (bf16_bytes as f64 / disk_bytes.max(1) as f64).into(),
                    ));
                }
                if codec == CodecId::Int4 {
                    codec_fields.push((
                        "codec_int4_shrink_vs_bf16",
                        (bf16_bytes as f64 / disk_bytes.max(1) as f64).into(),
                    ));
                }
            }
        }

        // quantized-domain scoring roofline: per quant-native store
        // kernel x int codec, on-disk GB/s with --quant-score on
        // (integer dot products over the encoded bytes, scales folded
        // in) vs off (decode-then-score), on the recoded stores from
        // the codec matrix above.  Measured in the low-Nq serving
        // regime where per-chunk decode cost is NOT amortized over a
        // large query batch — the I/O-bound pass Fig 3 profiles.
        // GB/s is on-disk bytes / wall time, so on the same store the
        // ratio is a pure hot-path speedup.  (lorif is omitted: its
        // factored kernel decodes in-kernel, gaining only cache
        // residency, not a scoring-loop win.)
        let mut roofline_fields: Vec<(&'static str, lorif::util::json::Value)> = Vec::new();
        {
            use lorif::attribution::logra::LograScorer;
            use lorif::attribution::trackstar::TrackStarScorer;
            use lorif::curvature::DenseCurvature;
            use lorif::store::{CodecId, QuantScore};
            use std::sync::Arc;

            let nq_r = 4usize;
            let rlayers: Vec<QueryLayer> = layers
                .iter()
                .map(|&(d1, d2)| QueryLayer {
                    g: Mat::random_normal(nq_r, d1 * d2, 1.0, &mut rng),
                    u: Mat::zeros(nq_r, d1),
                    v: Mat::zeros(nq_r, d2),
                })
                .collect();
            let qr = QueryGrads {
                n_query: nq_r,
                c: 1,
                proj_dims: layers.clone(),
                layers: rlayers,
            };

            println!("quant-score roofline (on-disk GB/s, Nq={nq_r}, k={k}):");
            println!("  kernel     codec  decode GB/s  quant GB/s  speedup");
            for codec in [CodecId::Int8, CodecId::Int4] {
                let base = dir.join(format!("codec_{}", codec.as_str()));
                let disk_bytes = ShardSet::open(&base)?.meta.total_bytes();
                let curv =
                    Arc::new(DenseCurvature::build(&ShardSet::open(&base)?, 0.1)?);
                let mut gbps = |s: &mut dyn Scorer| {
                    let t = time(3, || {
                        let _ = s.score_sink(&qr, SinkSpec::TopK(k)).unwrap();
                    });
                    disk_bytes as f64 / t / 1e9
                };
                let mut kernel_rates: Vec<(&'static str, f64, f64)> = Vec::new();
                {
                    let mut mk = |quant: QuantScore| -> anyhow::Result<GradDotScorer> {
                        let mut s = GradDotScorer::new(ShardSet::open(&base)?);
                        s.score_threads = 1;
                        s.prune = PruneMode::Off;
                        s.quant = quant;
                        Ok(s)
                    };
                    let d = gbps(&mut mk(QuantScore::Off)?);
                    let q = gbps(&mut mk(QuantScore::On)?);
                    kernel_rates.push(("graddot", d, q));
                }
                {
                    let mut mk = |quant: QuantScore| -> anyhow::Result<LograScorer> {
                        let mut s =
                            LograScorer::new(ShardSet::open(&base)?, Arc::clone(&curv));
                        s.score_threads = 1;
                        s.prune = PruneMode::Off;
                        s.quant = quant;
                        Ok(s)
                    };
                    let d = gbps(&mut mk(QuantScore::Off)?);
                    let q = gbps(&mut mk(QuantScore::On)?);
                    kernel_rates.push(("logra", d, q));
                }
                {
                    let mut mk = |quant: QuantScore| -> anyhow::Result<TrackStarScorer> {
                        let mut s = TrackStarScorer::new(
                            ShardSet::open(&base)?,
                            Arc::clone(&curv),
                        );
                        s.score_threads = 1;
                        s.prune = PruneMode::Off;
                        s.quant = quant;
                        Ok(s)
                    };
                    let d = gbps(&mut mk(QuantScore::Off)?);
                    let q = gbps(&mut mk(QuantScore::On)?);
                    kernel_rates.push(("trackstar", d, q));
                }
                for (kname, d, q) in kernel_rates {
                    println!(
                        "  {kname:<9}  {:<5}  {d:>11.2}  {q:>10.2}  {:>6.2}x",
                        codec.as_str(),
                        q / d.max(1e-12)
                    );
                    let (fd, fq, fs) = match (kname, codec) {
                        ("graddot", CodecId::Int8) => (
                            "roofline_graddot_int8_decode_gbps",
                            "roofline_graddot_int8_quant_gbps",
                            "roofline_graddot_int8_speedup",
                        ),
                        ("graddot", CodecId::Int4) => (
                            "roofline_graddot_int4_decode_gbps",
                            "roofline_graddot_int4_quant_gbps",
                            "roofline_graddot_int4_speedup",
                        ),
                        ("logra", CodecId::Int8) => (
                            "roofline_logra_int8_decode_gbps",
                            "roofline_logra_int8_quant_gbps",
                            "roofline_logra_int8_speedup",
                        ),
                        ("logra", CodecId::Int4) => (
                            "roofline_logra_int4_decode_gbps",
                            "roofline_logra_int4_quant_gbps",
                            "roofline_logra_int4_speedup",
                        ),
                        ("trackstar", CodecId::Int8) => (
                            "roofline_trackstar_int8_decode_gbps",
                            "roofline_trackstar_int8_quant_gbps",
                            "roofline_trackstar_int8_speedup",
                        ),
                        ("trackstar", CodecId::Int4) => (
                            "roofline_trackstar_int4_decode_gbps",
                            "roofline_trackstar_int4_quant_gbps",
                            "roofline_trackstar_int4_speedup",
                        ),
                        _ => unreachable!("kernel x codec table is exhaustive"),
                    };
                    roofline_fields.push((fd, d.into()));
                    roofline_fields.push((fq, q.into()));
                    roofline_fields.push((fs, (q / d.max(1e-12)).into()));
                }
            }
        }

        // clustered retrieval tier: a separated-blob corpus written in
        // shuffled arrival order, recoded with `--cluster` so each
        // summary chunk is one tight cluster, then scanned best-first.
        // Exact mode must return the unclustered full scan's top-k
        // bit-for-bit while reading fewer bytes; `--prune recall=x`
        // trades certified-recall for I/O.  The recall/latency/bytes
        // curve is persisted for the CI perf-smoke assertions.
        let mut cluster_fields: Vec<(&'static str, lorif::util::json::Value)> = Vec::new();
        {
            use lorif::store::{recode_store, RecodeOptions};

            let kc = 32usize; // separated blobs, one k-means center each
            let n_c = if quick() { 2048usize } else { 4096 };
            let grid_c = 64usize; // chunk is at most one blob
            let nq_c = 8usize;
            let dim: usize = layers.iter().map(|&(d1, d2)| d1 * d2).sum();

            // well-separated random centers; shuffled arrival order
            let centers = Mat::random_normal(kc, dim, 1.0, &mut rng);
            let mut assign_c: Vec<usize> = (0..n_c).map(|t| t % kc).collect();
            rng.shuffle(&mut assign_c);

            let src_base = dir.join("ivf_src");
            let meta = StoreMeta {
                kind: StoreKind::Dense,
                tier: "small".into(),
                f: 4,
                c: 1,
                layers: layers.clone(),
                n_examples: 0,
                shards: None,
                summary_chunk: None,
                codec: lorif::store::CodecId::Bf16,
            };
            let mut w = StoreWriter::create(&src_base, meta)?;
            w.set_summary_chunk(grid_c)?;
            let mut lg_c: Vec<LayerGrads> = Vec::new();
            let mut off = 0usize;
            for &(d1, d2) in &layers {
                let d = d1 * d2;
                let mut g = Mat::zeros(n_c, d);
                for t in 0..n_c {
                    let cen = centers.row(assign_c[t]);
                    for (x, slot) in g.row_mut(t).iter_mut().enumerate() {
                        *slot = cen[off + x] * (1.0 + 0.05 * rng.normal() as f32);
                    }
                }
                off += d;
                lg_c.push(LayerGrads { g, u: Mat::zeros(n_c, d1), v: Mat::zeros(n_c, d2) });
            }
            w.append(&ExtractBatch { losses: vec![0.0; n_c], layers: lg_c, valid: n_c })?;
            w.finalize()?;

            let dst_base = dir.join("ivf_clustered");
            let rep = recode_store(
                &src_base,
                &dst_base,
                &RecodeOptions { cluster: Some(kc), ..Default::default() },
            )?;
            assert_eq!(rep.cluster, Some(kc), "recode did not attach cluster metadata");

            // queries aligned with the blob that seeds k-means centroid 0
            // (the arrival-order record at storage position 0), so the
            // reordered store concentrates their top-k in very few chunks
            let hot = assign_c[0];
            let mut qlayers_c: Vec<QueryLayer> = Vec::new();
            let mut off = 0usize;
            for &(d1, d2) in &layers {
                let d = d1 * d2;
                let mut g = Mat::zeros(nq_c, d);
                for qi in 0..nq_c {
                    let cen = centers.row(hot);
                    for (x, slot) in g.row_mut(qi).iter_mut().enumerate() {
                        *slot = cen[off + x] + 0.02 * rng.normal() as f32;
                    }
                }
                off += d;
                qlayers_c.push(QueryLayer {
                    g,
                    u: Mat::zeros(nq_c, d1),
                    v: Mat::zeros(nq_c, d2),
                });
            }
            let qc =
                QueryGrads { n_query: nq_c, c: 1, proj_dims: layers.clone(), layers: qlayers_c };

            let mut src_scorer = GradDotScorer::new(ShardSet::open(&src_base)?);
            src_scorer.score_threads = 1;
            let mut dst_scorer = GradDotScorer::new(ShardSet::open(&dst_base)?);
            dst_scorer.score_threads = 1;

            // unclustered full scan: the reference answer + byte budget
            src_scorer.prune = PruneMode::Off;
            let r_ref = src_scorer.score_sink(&qc, SinkSpec::TopK(k))?;
            let bytes_full = r_ref.bytes_read;
            let topk_ref = r_ref.topk(k);

            // unclustered exact pruning: arrival order scatters every
            // blob across every chunk, so the summary bounds barely help
            src_scorer.prune = PruneMode::Exact;
            let r_src_exact = src_scorer.score_sink(&qc, SinkSpec::TopK(k))?;
            assert_eq!(r_src_exact.topk(k), topk_ref, "unclustered exact pruning diverged");

            // clustered exact: bit-identical top-k, fewer bytes
            dst_scorer.prune = PruneMode::Exact;
            let r_exact = dst_scorer.score_sink(&qc, SinkSpec::TopK(k))?;
            assert_eq!(
                r_exact.topk(k),
                topk_ref,
                "clustered exact top-k diverged from the unclustered full scan"
            );
            assert_eq!(
                r_exact.bytes_read + r_exact.bytes_skipped,
                bytes_full,
                "best-first byte ledger broken"
            );
            assert!(
                r_exact.bytes_read <= bytes_full,
                "clustered exact mode read more than the full scan"
            );
            let t_exact = time(3, || {
                let _ = dst_scorer.score_sink(&qc, SinkSpec::TopK(k)).unwrap();
            });

            // per-query latency distribution through the telemetry
            // histogram (same log-bucketed quantiles the server's
            // `stats`/`metrics` verbs report), persisted so the CI
            // perf-smoke artifact tracks tail latency per PR
            let hist = lorif::telemetry::Histogram::default();
            let lat_iters = if quick() { 8usize } else { 32 };
            for _ in 0..lat_iters {
                let t0 = Instant::now();
                let _ = dst_scorer.score_sink(&qc, SinkSpec::TopK(k))?;
                hist.observe_dur(t0.elapsed());
            }
            println!(
                "retrieval tier latency over {lat_iters} queries: p50 {:.1} ms | \
                 p95 {:.1} ms | p99 {:.1} ms",
                hist.p50() * 1e3,
                hist.p95() * 1e3,
                hist.p99() * 1e3
            );
            cluster_fields.push(("latency_p50", hist.p50().into()));
            cluster_fields.push(("latency_p95", hist.p95().into()));
            cluster_fields.push(("latency_p99", hist.p99().into()));

            println!(
                "retrieval tier (n={n_c}, {kc} blobs, grid {grid_c}, k={k}): full scan \
                 {bytes_full} B | unclustered exact {} B | clustered exact {} B \
                 ({:.1}% of full, {} of {} chunks skipped)",
                r_src_exact.bytes_read,
                r_exact.bytes_read,
                100.0 * r_exact.bytes_read as f64 / bytes_full.max(1) as f64,
                r_exact.chunks_skipped,
                (n_c + grid_c - 1) / grid_c
            );

            let overlap_vs_ref = |topk: &Vec<Vec<usize>>| -> f64 {
                let inter: usize = topk_ref
                    .iter()
                    .zip(topk)
                    .map(|(a, b)| a.iter().filter(|i| b.contains(i)).count())
                    .sum();
                inter as f64 / (nq_c * k) as f64
            };

            cluster_fields.push(("cluster_k", kc.into()));
            cluster_fields.push(("cluster_grid", grid_c.into()));
            cluster_fields.push(("cluster_n", n_c.into()));
            cluster_fields.push(("cluster_full_scan_bytes", (bytes_full as usize).into()));
            cluster_fields
                .push(("cluster_src_exact_bytes_read", (r_src_exact.bytes_read as usize).into()));
            cluster_fields.push(("cluster_exact_bytes_read", (r_exact.bytes_read as usize).into()));
            cluster_fields.push(("cluster_exact_ms", (t_exact * 1e3).into()));
            cluster_fields.push(("cluster_exact_overlap_at_k", 1.0f64.into()));

            // recall curve: certified-recall early stop vs bytes/latency
            for (key_bytes, key_ms, key_overlap, target) in [
                (
                    "cluster_recall90_bytes_read",
                    "cluster_recall90_ms",
                    "cluster_recall90_overlap_at_k",
                    0.90f32,
                ),
                (
                    "cluster_recall99_bytes_read",
                    "cluster_recall99_ms",
                    "cluster_recall99_overlap_at_k",
                    0.99,
                ),
                (
                    "cluster_recall100_bytes_read",
                    "cluster_recall100_ms",
                    "cluster_recall100_overlap_at_k",
                    1.0,
                ),
            ] {
                dst_scorer.prune = PruneMode::Recall(target);
                let r = dst_scorer.score_sink(&qc, SinkSpec::TopK(k))?;
                let overlap = overlap_vs_ref(&r.topk(k));
                assert!(
                    overlap >= target as f64,
                    "recall={target}: certified stop delivered overlap {overlap}"
                );
                let t_r = time(3, || {
                    let _ = dst_scorer.score_sink(&qc, SinkSpec::TopK(k)).unwrap();
                });
                println!(
                    "retrieval tier recall={target}: {} B read ({:.1}% of full) | \
                     overlap@{k} {overlap:.3} | {:.1} ms",
                    r.bytes_read,
                    100.0 * r.bytes_read as f64 / bytes_full.max(1) as f64,
                    t_r * 1e3
                );
                if (target - 0.99).abs() < 1e-6 {
                    assert!(
                        r.bytes_read * 10 <= bytes_full,
                        "recall=0.99 read {} B, over 10% of the {} B full scan",
                        r.bytes_read,
                        bytes_full
                    );
                    assert!(overlap >= 0.99, "recall=0.99 overlap {overlap} below target");
                }
                cluster_fields.push((key_bytes, (r.bytes_read as usize).into()));
                cluster_fields.push((key_ms, (t_r * 1e3).into()));
                cluster_fields.push((key_overlap, overlap.into()));
            }
        }

        // persist the sink + pruning comparison for the CI perf-smoke
        // artifact
        let mut fields: Vec<(&'static str, lorif::util::json::Value)> = vec![
            ("n_train", n.into()),
            ("n_query", nq.into()),
            ("k", k.into()),
            ("shards", shards.into()),
            ("quick", quick().into()),
            ("full_ms", (t_full * 1e3).into()),
            ("topk_ms", (t_topk * 1e3).into()),
            ("full_peak_elems", r_full.peak_sink_elems.into()),
            ("topk_peak_elems", r_topk.peak_sink_elems.into()),
            ("prune_full_ms", (t_noprune * 1e3).into()),
            ("prune_ms", (t_prune * 1e3).into()),
            ("cache_cold_ms", (t_cache_cold * 1e3).into()),
            ("cache_warm_ms", (t_cache_warm * 1e3).into()),
            ("cache_warm_hits", warm_hits.into()),
        ];
        fields.extend(bytes_by_k);
        fields.extend(codec_fields);
        fields.extend(roofline_fields);
        fields.extend(cluster_fields);
        let doc = lorif::util::json::obj(fields);
        let out_dir = std::path::PathBuf::from("work/bench/results");
        std::fs::create_dir_all(&out_dir)?;
        let out = out_dir.join("perf_smoke.json");
        std::fs::write(&out, doc.to_string())?;
        println!("sink + pruning comparison saved to {}", out.display());
    }

    xla_scorer_bench(&mut rng);
    Ok(())
}

/// XLA scorer artifact vs Rust-native scorer (single layer shape).
#[cfg(feature = "xla")]
fn xla_scorer_bench(rng: &mut Rng) {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("(artifacts missing: skipping XLA scorer comparison)");
        return;
    }
    let mut run = || -> anyhow::Result<()> {
        let rt = lorif::runtime::Runtime::new(std::path::Path::new("artifacts"))?;
        let exe = match rt.load("score_16x48_c1_r128") {
            Ok(exe) => exe,
            Err(_) => return Ok(()),
        };
        let (b, d1, d2, c, r) = (512usize, 16usize, 48usize, 1usize, 128usize);
        let mk = |n: usize, rng: &mut Rng| -> Vec<f32> {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 1.0);
            v
        };
        let uq = lorif::runtime::lit_f32(&mk(d1 * c, rng), &[d1 as i64, c as i64])?;
        let vq = lorif::runtime::lit_f32(&mk(d2 * c, rng), &[d2 as i64, c as i64])?;
        let bu = lorif::runtime::lit_f32(&mk(b * d1 * c, rng), &[b as i64, d1 as i64, c as i64])?;
        let bv = lorif::runtime::lit_f32(&mk(b * d2 * c, rng), &[b as i64, d2 as i64, c as i64])?;
        let gq = lorif::runtime::lit_f32(&mk(r, rng), &[r as i64])?;
        let gt = lorif::runtime::lit_f32(&mk(b * r, rng), &[b as i64, r as i64])?;
        let w = lorif::runtime::lit_f32(&mk(r, rng), &[r as i64])?;
        let lam = lorif::runtime::lit_f32(&[0.5], &[1])?;
        let t = time(20, || {
            let _ = rt.exec(&exe, &[&uq, &vq, &bu, &bv, &gq, &gt, &w, &lam]).unwrap();
        });
        println!(
            "XLA pallas scorer (B={b}, one layer): {:.1} Mpairs/s ({:.3} ms)",
            b as f64 / t / 1e6,
            t * 1e3
        );
        Ok(())
    };
    if let Err(e) = run() {
        println!("(XLA scorer comparison failed: {e})");
    }
}

#[cfg(not(feature = "xla"))]
fn xla_scorer_bench(_rng: &mut Rng) {
    println!("(built without the xla feature: skipping XLA scorer comparison)");
}

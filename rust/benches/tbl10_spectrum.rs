//! Table 10 + Figure 6: spectral concentration of the projected
//! training-gradient matrix G — EVR at the top {10, 25, 50}% of singular
//! directions per module type, plus the full EVR(r) curve (Fig 6).
//!
//! The exact (sample) spectrum comes from the eigenvalues of the N x N
//! Gram matrix G G^T on an N=192 sample — identical nonzero spectrum to
//! G^T G without forming D x D.
//!
//! Expected shape: moderate concentration (EVR@10% ~0.4–0.5, @50%
//! ~0.7–0.85), attn more concentrated than mlp, stable across tiers.

use lorif::bench_support::{Session, Table};
use lorif::index::Stage1Options;
use lorif::linalg::eigh;
use lorif::model::spec::{Module, Tier};

fn spectrum_evr(evals_desc: &[f32], frac: f64) -> f64 {
    let total: f64 = evals_desc.iter().map(|&x| x.max(0.0) as f64).sum();
    let k = ((evals_desc.len() as f64 * frac).round() as usize).max(1);
    let top: f64 = evals_desc[..k.min(evals_desc.len())].iter().map(|&x| x.max(0.0) as f64).sum();
    if total > 0.0 { top / total } else { 0.0 }
}

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(
        "Table 10: spectral concentration of G (EVR at top p% directions)",
        &["tier", "module", "D", "EVR@10%", "EVR@25%", "EVR@50%"],
    );
    let mut fig6 = Table::new(
        "Fig 6: cumulative EVR(r) curve (small tier, f=4, attn layer 0)",
        &["r", "EVR"],
    );
    for tier in [Tier::Small, Tier::Medium, Tier::Large] {
        let s = Session::with_tier(tier);
        let f = if tier == Tier::Small { 4 } else { 8 };
        let (p, train, _, params) = s.prepared(f, 1, 64)?;
        let lit = p.params_literal(&params)?;
        p.stage1(&lit, &train, Stage1Options::default())?;
        let reader = lorif::store::ShardSet::open(&p.dense_base())?;
        let n = 192.min(reader.meta.n_examples);
        let chunk = reader.read_range(0, n)?;
        let layers = p.cfg.tier.spec().tracked_layers();

        for module in [Module::Attn, Module::Mlp] {
            // representative layer of this module type: first matching
            let Some((l, _)) = layers.iter().enumerate().find(|(_, t)| t.module == module)
            else { continue };
            let g = chunk.layers[l].dense();
            let gram = g.matmul_nt(g); // (n, n): same nonzero spectrum as G^T G
            let (mut vals, _) = eigh::eigh(&gram);
            vals.reverse(); // descending
            let (d1, d2) = reader.meta.layers[l];
            table.row(vec![
                tier.name().into(),
                module.as_str().into(),
                (d1 * d2).to_string(),
                format!("{:.2}", spectrum_evr(&vals, 0.10)),
                format!("{:.2}", spectrum_evr(&vals, 0.25)),
                format!("{:.2}", spectrum_evr(&vals, 0.50)),
            ]);
            if tier == Tier::Small && module == Module::Attn {
                let total: f64 = vals.iter().map(|&x| x.max(0.0) as f64).sum();
                let mut acc = 0.0;
                for (i, &v) in vals.iter().enumerate() {
                    acc += v.max(0.0) as f64;
                    if i % (vals.len() / 12).max(1) == 0 || i + 1 == vals.len() {
                        fig6.row(vec![(i + 1).to_string(), format!("{:.3}", acc / total)]);
                    }
                }
            }
        }
    }
    table.print();
    table.save("tbl10")?;
    fig6.print();
    fig6.save("fig6")?;
    Ok(())
}

//! Figure 3: query-time latency breakdown — loading gradients vs GPU
//! (here CPU) computation, same effective D for every method.
//!
//! Paper: LoGRA is I/O-bound (96% of 211 s loading); rank-1
//! factorization alone cuts I/O ~40x; adding truncated SVD cuts compute,
//! 30x total.  Expected shape here: LoGRA load >> LoRIF load (the store
//! is min(d1,d2)/2 smaller) and "ours" total < "rank-1 only" total.

use lorif::app::{build_store_scorer, Method};
use lorif::attribution::ablation::FactoredDenseKScorer;
use lorif::attribution::Scorer;
use lorif::bench_support::{fmt_mb, fmt_s, Session, Table};
use lorif::index::Stage1Options;
use lorif::store::ShardSet;

fn main() -> anyhow::Result<()> {
    let s = Session::new();
    let f = 4;
    let (p, train, queries, params) = s.prepared(f, 1, 128)?;
    let lit = p.params_literal(&params)?;
    p.stage1(&lit, &train, Stage1Options::default())?;
    let qg = p.query_grads(&lit, &queries)?;

    let mut table = Table::new(
        &format!(
            "Fig 3: latency breakdown (N={}, Nq={}, f={f}, r=128)",
            train.len(),
            queries.len()
        ),
        &["method", "load", "compute", "precondition", "total", "index size"],
    );

    let mut run = |name: &str, scorer: &mut dyn Scorer| -> anyhow::Result<()> {
        // warm the page cache consistently: one throwaway pass
        let rep = scorer.score(&qg)?;
        let rep = { let _ = rep; scorer.score(&qg)? };
        let load = rep.timer.get("load").as_secs_f64();
        let compute = rep.timer.get("compute").as_secs_f64();
        let pre = rep.timer.get("precondition").as_secs_f64();
        table.row(vec![
            name.into(),
            fmt_s(load),
            fmt_s(compute),
            fmt_s(pre),
            fmt_s(load + compute + pre),
            fmt_mb(rep.bytes_read),
        ]);
        Ok(())
    };

    let mut logra = build_store_scorer(&p, Method::Logra)?;
    run("LoGRA (dense, dense K)", &mut logra)?;

    let (dense_curv, _) = p.stage2_dense()?;
    let mut rank1 =
        FactoredDenseKScorer::new(ShardSet::open(&p.factored_base())?, dense_curv);
    run("rank-1 factorization only", &mut rank1)?;

    let mut lorif = build_store_scorer(&p, Method::Lorif)?;
    run("Ours (rank-1 + truncated SVD)", &mut lorif)?;

    // extension over the paper: reuse the stage-2 train projections
    // (U_r Sigma_r rows are free by-products of the rSVD) instead of
    // re-projecting reconstructed gradients at query time — removes the
    // O(N D r) term that dominates compute when r > Nq
    let (curv, _) = p.stage2_lorif()?;
    let mut cached =
        lorif::attribution::LorifScorer::new(ShardSet::open(&p.factored_base())?, curv);
    cached.cached_projections = true;
    run("Ours + cached projections", &mut cached)?;

    table.print();
    table.save("fig3")?;
    Ok(())
}

//! Table 1: main comparison on the small tier (GPT2-small stand-in) —
//! LDS, persistent storage, and query latency across storage regimes.
//!
//! Regime mapping (paper f in {8,16,32} at GPT2 scale -> ours):
//!   high   f=2  | medium f=4 | low f=8, with LoRIF using a smaller
//! factored store (or higher D at matched storage) in each regime.
//! Expected shape: EK-FAC best LDS but ~10^3x slower; RepSim tiny+fast
//! but near-zero LDS; LoRIF matches/beats LoGRA per regime with ~5-10x
//! less storage.

use lorif::app::Method;
use lorif::bench_support::{fmt_mb, fmt_pm, fmt_s, Session, Table};

fn main() -> anyhow::Result<()> {
    let s = Session::new();
    let mut table = Table::new(
        "Table 1: main comparison (small tier)",
        &["method", "f", "c", "r", "LDS", "storage", "latency"],
    );
    let mut add = |m: lorif::bench_support::Measurement| {
        let c = if m.method == "lorif" { m.c.to_string() } else { "—".into() };
        let r = if m.method == "lorif" { m.r.to_string() } else { "—".into() };
        table.row(vec![
            m.method.clone(),
            m.f.to_string(),
            c,
            r,
            fmt_pm(m.lds),
            fmt_mb(m.storage_bytes),
            fmt_s(m.latency_total()),
        ]);
    };

    // contextual baselines
    add(s.measure(Method::Ekfac, 1, 1, 64, true, false)?);
    add(s.measure(Method::RepSim, 4, 1, 64, true, false)?);

    // high storage regime (f = 2)
    add(s.measure(Method::GradDot, 2, 1, 64, true, false)?);
    add(s.measure(Method::TrackStar, 2, 1, 64, true, false)?);
    add(s.measure(Method::Logra, 2, 1, 64, true, false)?);
    add(s.measure(Method::Lorif, 2, 4, 384, true, false)?);

    // medium storage regime (f = 4)
    add(s.measure(Method::TrackStar, 4, 1, 64, true, false)?);
    add(s.measure(Method::Logra, 4, 1, 64, true, false)?);
    add(s.measure(Method::Lorif, 2, 1, 256, true, false)?);

    // low storage regime (f = 8)
    add(s.measure(Method::TrackStar, 8, 1, 64, true, false)?);
    add(s.measure(Method::Logra, 8, 1, 64, true, false)?);
    add(s.measure(Method::Lorif, 4, 1, 128, true, false)?);

    table.print();
    table.save("tbl1")?;
    Ok(())
}

//! Figure 4: the quality–storage Pareto frontier, LoRIF vs LoGRA.
//!
//! (a) LDS vs storage on the small tier (GPT2-small stand-in);
//! (b) tail-patch vs storage on the medium tier (OLMo-3-7B stand-in),
//!     run with `LORIF_FIG4_TIER=medium`.
//! Expected shape: at matched storage, LoRIF (larger D via factorized
//! storage) sits above LoGRA; the frontier improves.

use lorif::app::Method;
use lorif::bench_support::{fmt_mb, fmt_pm, Session, Table};
use lorif::model::spec::Tier;

fn main() -> anyhow::Result<()> {
    let medium = std::env::var("LORIF_FIG4_TIER").as_deref() == Ok("medium");
    if medium {
        panel_b()
    } else {
        panel_a()?;
        panel_b()
    }
}

fn panel_a() -> anyhow::Result<()> {
    let s = Session::new();
    let mut table = Table::new(
        "Fig 4a: LDS vs storage (small tier)",
        &["method", "f", "c", "storage", "LDS"],
    );
    for f in [16, 8, 4] {
        let m = s.measure(Method::Logra, f, 1, 64, true, false)?;
        table.row(vec![
            "LoGRA".into(), f.to_string(), "—".into(),
            fmt_mb(m.storage_bytes), fmt_pm(m.lds),
        ]);
    }
    for (f, r) in [(8, 64), (4, 128), (2, 256)] {
        let m = s.measure(Method::Lorif, f, 1, r, true, false)?;
        table.row(vec![
            "LoRIF".into(), f.to_string(), "1".into(),
            fmt_mb(m.storage_bytes), fmt_pm(m.lds),
        ]);
    }
    for c in [2, 4] {
        let m = s.measure(Method::Lorif, 2, c, 256, true, false)?;
        table.row(vec![
            "LoRIF".into(), "2".into(), c.to_string(),
            fmt_mb(m.storage_bytes), fmt_pm(m.lds),
        ]);
    }
    table.print();
    table.save("fig4a")?;
    Ok(())
}

fn panel_b() -> anyhow::Result<()> {
    let s = Session::with_tier(Tier::Medium);
    let mut table = Table::new(
        "Fig 4b: tail-patch vs storage (medium tier)",
        &["method", "f", "c", "storage", "tail-patch"],
    );
    for f in [16, 8] {
        let m = s.measure(Method::Logra, f, 1, 64, false, true)?;
        table.row(vec![
            "LoGRA".into(), f.to_string(), "—".into(),
            fmt_mb(m.storage_bytes), fmt_pm(m.tail_patch),
        ]);
    }
    for (f, r) in [(8, 64), (4, 128)] {
        let m = s.measure(Method::Lorif, f, 1, r, false, true)?;
        table.row(vec![
            "LoRIF".into(), f.to_string(), "1".into(),
            fmt_mb(m.storage_bytes), fmt_pm(m.tail_patch),
        ]);
    }
    table.print();
    table.save("fig4b")?;
    Ok(())
}

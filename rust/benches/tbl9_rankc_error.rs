//! Table 9: rank-c factorization error of projected per-example
//! gradients — relative Frobenius error and EVR, grouped by module type
//! (attn vs mlp), per tier.
//!
//! Expected shape (paper App. E.1): c=1 error ~0.5–0.85 with mlp modules
//! less compressible than attn; error drops substantially at c=4; the
//! approximation does not degrade at larger tiers.

use lorif::bench_support::{Session, Table};
use lorif::grads::factorize;
use lorif::index::Stage1Options;
use lorif::linalg::Mat;
use lorif::model::spec::{Module, Tier};

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(
        "Table 9: rank-c factorization error (relative Frobenius / EVR)",
        &["tier", "module", "c=1 err", "c=1 EVR", "c=4 err", "c=4 EVR"],
    );
    for tier in [Tier::Small, Tier::Medium, Tier::Large] {
        let s = Session::with_tier(tier);
        let f = if tier == Tier::Small { 4 } else { 8 };
        let (p, train, _, params) = s.prepared(f, 1, 64)?;
        let lit = p.params_literal(&params)?;
        p.stage1(&lit, &train, Stage1Options::default())?;
        let reader = lorif::store::ShardSet::open(&p.dense_base())?;
        let sample = 256.min(reader.meta.n_examples);
        let chunk = reader.read_range(0, sample)?;

        let layers = p.cfg.tier.spec().tracked_layers();
        for module in [Module::Attn, Module::Mlp] {
            let mut stats = [(0.0f64, 0.0f64), (0.0f64, 0.0f64)]; // (err, evr) for c=1,4
            let mut count = 0usize;
            for (l, tl) in layers.iter().enumerate() {
                if tl.module != module {
                    continue;
                }
                let (d1, d2) = reader.meta.layers[l];
                let g = chunk.layers[l].dense();
                for ex in (0..sample).step_by(4) {
                    let gm = Mat::from_vec(d1, d2, g.row(ex).to_vec());
                    if gm.frob_norm() == 0.0 {
                        continue;
                    }
                    for (ci, &c) in [1usize, 4].iter().enumerate() {
                        let iters = if c == 1 { 8 } else { 16 };
                        let (u, v) = factorize::poweriter(&gm, c, iters);
                        let (err, evr) = factorize::reconstruction_error(&gm, &u, &v);
                        stats[ci].0 += err as f64;
                        stats[ci].1 += evr as f64;
                    }
                    count += 1;
                }
            }
            let n = count.max(1) as f64;
            table.row(vec![
                tier.name().into(),
                module.as_str().into(),
                format!("{:.3}", stats[0].0 / n),
                format!("{:.1}%", 100.0 * stats[0].1 / n),
                format!("{:.3}", stats[1].0 / n),
                format!("{:.1}%", 100.0 * stats[1].1 / n),
            ]);
        }
    }
    table.print();
    table.save("tbl9")?;
    Ok(())
}

//! Tables 5/6/7: preprocessing time — stage 1 (gradient computation +
//! factorization + storage) and stage 2 (inverse-Hessian approximation)
//! for every tier.
//!
//! Expected shape (per paper App. C): stage 1 is nearly flat in f and c=1
//! factorization adds negligible time; stage 2 grows steeply as f drops
//! (D grows) and is far cheaper for LoRIF's rSVD than LoGRA's dense
//! assembly at large D.

use lorif::bench_support::{fmt_s, Session, Table};
use lorif::index::Stage1Options;
use lorif::model::spec::Tier;

fn main() -> anyhow::Result<()> {
    // small tier: the Table 5 grid
    let s = Session::new();
    let mut table = Table::new(
        "Table 5: preprocessing time (small tier)",
        &["method", "f", "c", "r", "stage 1", "stage 2", "total"],
    );
    let grid: &[(&str, usize, usize, usize)] = &[
        ("LoGRA", 16, 1, 0),
        ("LoGRA", 8, 1, 0),
        ("LoGRA", 4, 1, 0),
        ("LoGRA", 2, 1, 0),
        ("LoRIF", 8, 1, 64),
        ("LoRIF", 4, 1, 128),
        ("LoRIF", 2, 1, 256),
        ("LoRIF", 2, 4, 384),
    ];
    for &(method, f, c, r) in grid {
        let p = s.pipeline(f, c, r.max(1))?;
        let (train, _) = p.corpus()?;
        let params = p.base_params(&train)?;
        let lit = p.params_literal(&params)?;
        // clear cached index for THIS config so times are real
        let _ = std::fs::remove_dir_all(p.cfg.index_dir());
        let is_lorif = method == "LoRIF";
        let s1 = p.stage1(
            &lit,
            &train,
            Stage1Options {
                write_factored: is_lorif,
                write_dense: !is_lorif,
                write_embeddings: false,
            },
        )?;
        let (t2_secs, r_str) = if is_lorif {
            let (_, d) = p.stage2_lorif()?;
            (d.as_secs_f64(), r.to_string())
        } else {
            let (_, d) = p.stage2_dense()?;
            (d.as_secs_f64(), "—".to_string())
        };
        table.row(vec![
            method.into(),
            f.to_string(),
            if is_lorif { c.to_string() } else { "—".into() },
            r_str,
            fmt_s(s1.wall.as_secs_f64()),
            fmt_s(t2_secs),
            fmt_s(s1.wall.as_secs_f64() + t2_secs),
        ]);
    }
    table.print();
    table.save("tbl5")?;

    // medium/large tiers: Tables 6/7 (reduced grid)
    for tier in [Tier::Medium, Tier::Large] {
        let s = Session::with_tier(tier);
        let mut table = Table::new(
            &format!("Table {}: preprocessing time ({} tier)", if tier == Tier::Medium { 6 } else { 7 }, tier.name()),
            &["method", "f", "c", "r", "stage 1", "stage 2", "total"],
        );
        let (f_a, f_b) = if tier == Tier::Medium { (8, 4) } else { (16, 8) };
        for &(method, f, r) in
            &[("LoGRA", f_a, 0usize), ("LoRIF", f_a, 64), ("LoRIF", f_b, 128)]
        {
            let p = s.pipeline(f, 1, r.max(1))?;
            let (train, _) = p.corpus()?;
            let params = p.base_params(&train)?;
            let lit = p.params_literal(&params)?;
            let _ = std::fs::remove_dir_all(p.cfg.index_dir());
            let is_lorif = method == "LoRIF";
            let s1 = p.stage1(
                &lit,
                &train,
                Stage1Options {
                    write_factored: is_lorif,
                    write_dense: !is_lorif,
                    write_embeddings: false,
                },
            )?;
            let t2 = if is_lorif {
                p.stage2_lorif()?.1.as_secs_f64()
            } else {
                p.stage2_dense()?.1.as_secs_f64()
            };
            table.row(vec![
                method.into(),
                f.to_string(),
                if is_lorif { "1".into() } else { "—".into() },
                if is_lorif { r.to_string() } else { "—".into() },
                fmt_s(s1.wall.as_secs_f64()),
                fmt_s(t2),
                fmt_s(s1.wall.as_secs_f64() + t2),
            ]);
        }
        table.print();
        table.save(&format!("tbl_preproc_{}", tier.name()))?;
    }
    Ok(())
}

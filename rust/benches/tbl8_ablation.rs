//! Table 8: separating LoRIF's two low-rank components.
//!
//!   LoRIF w/o truncated SVD  = rank-c factors + dense Cholesky K
//!                              (OOM above the dense limit — demonstrated
//!                              by dropping LORIF_DENSE_LIMIT);
//!   LoRIF w/o factorization  = dense gradients + Woodbury curvature;
//!   LoRIF                    = both components.
//!
//! Expected shape: w/o-SVD keeps storage small but hits OOM at large D;
//! w/o-fact keeps quality but restores O(D) storage; full LoRIF gets
//! both cheap.

use lorif::app::{build_store_scorer, Method};
use lorif::attribution::ablation::{DenseWoodburyScorer, FactoredDenseKScorer};
use lorif::attribution::Scorer;
use lorif::bench_support::{fmt_mb, fmt_pm, fmt_s, lds_protocol, Session, Table};
use lorif::eval::LdsActuals;
use lorif::index::Stage1Options;

fn main() -> anyhow::Result<()> {
    let s = Session::new();
    let mut table = Table::new(
        "Table 8: component ablation (small tier)",
        &["variant", "f", "c", "r", "LDS", "storage", "latency"],
    );
    for (f, c, r) in [(4usize, 1usize, 128usize), (2, 1, 256)] {
        let (p, train, queries, params) = s.prepared(f, c, r)?;
        let lit = p.params_literal(&params)?;
        p.stage1(&lit, &train, Stage1Options::default())?;
        let qg = p.query_grads(&lit, &queries)?;
        let actuals = LdsActuals::get(&p, &lds_protocol(), &train, &queries)?;

        // w/o truncated SVD (factors + dense K)
        let row = match p.stage2_dense() {
            Ok((curv, _)) => {
                let mut sc =
                    FactoredDenseKScorer::new(lorif::store::ShardSet::open(&p.factored_base())?, curv);
                let rep = sc.score(&qg)?;
                vec![
                    "LoRIF w/o truncated SVD".into(),
                    f.to_string(), c.to_string(), "—".into(),
                    fmt_pm(Some(actuals.lds(rep.scores()))),
                    fmt_mb(sc.index_bytes()),
                    fmt_s(rep.timer.total().as_secs_f64()),
                ]
            }
            Err(e) => vec![
                "LoRIF w/o truncated SVD".into(),
                f.to_string(), c.to_string(), "—".into(),
                format!("OOM ({e})"), "—".into(), "—".into(),
            ],
        };
        table.row(row);

        // w/o rank factorization (dense + Woodbury)
        let set = lorif::store::ShardSet::open(&p.dense_base())?;
        let curv = lorif::curvature::TruncatedCurvature::build(
            &set, r, p.cfg.rsvd_oversample, p.cfg.rsvd_power_iters,
            p.cfg.lambda_factor, p.cfg.seed,
        )?;
        let mut sc = DenseWoodburyScorer::new(lorif::store::ShardSet::open(&p.dense_base())?, curv);
        let rep = sc.score(&qg)?;
        table.row(vec![
            "LoRIF w/o factorization".into(),
            f.to_string(), "—".into(), r.to_string(),
            fmt_pm(Some(actuals.lds(rep.scores()))),
            fmt_mb(sc.index_bytes()),
            fmt_s(rep.timer.total().as_secs_f64()),
        ]);

        // full LoRIF
        let mut sc = build_store_scorer(&p, Method::Lorif)?;
        let rep = sc.score(&qg)?;
        table.row(vec![
            "LoRIF".into(),
            f.to_string(), c.to_string(), r.to_string(),
            fmt_pm(Some(actuals.lds(rep.scores()))),
            fmt_mb(sc.index_bytes()),
            fmt_s(rep.timer.total().as_secs_f64()),
        ]);
    }

    // OOM demonstration: the dense-K path refuses at large D under a
    // memory budget (the paper's "OOM" rows)
    {
        std::env::set_var("LORIF_DENSE_LIMIT", "2000000"); // 8 MB of f32
        let (p, train, _, params) = s.prepared(2, 1, 256)?;
        let lit = p.params_literal(&params)?;
        p.stage1(&lit, &train, Stage1Options::default())?;
        let err = p.stage2_dense().err();
        std::env::remove_var("LORIF_DENSE_LIMIT");
        table.row(vec![
            "w/o SVD @ 8MB budget".into(),
            "2".into(), "1".into(), "—".into(),
            err.map(|e| format!("OOM: {e}")).unwrap_or("unexpected OK".into()),
            "—".into(), "—".into(),
        ]);
    }
    table.print();
    table.save("tbl8")?;
    Ok(())
}

//! Figure 2b: truncated-SVD curvature quality — LDS vs truncation rank r
//! (rank factorization NOT used, exactly like the paper's panel).
//!
//! r = 0 discards curvature (reduces to GradDot); the full-rank baseline
//! is LoGRA's dense Cholesky.  Expected shape: LDS approaches the
//! full-rank level for r << D.

use lorif::app::{build_store_scorer, Method};
use lorif::attribution::ablation::DenseWoodburyScorer;
use lorif::attribution::Scorer;
use lorif::bench_support::{fmt_pm, lds_protocol, Session, Table};
use lorif::curvature::TruncatedCurvature;
use lorif::eval::LdsActuals;
use lorif::index::Stage1Options;

fn main() -> anyhow::Result<()> {
    let s = Session::new();
    let mut table = Table::new(
        "Fig 2b: LDS vs curvature truncation rank r (no factorization)",
        &["f", "D", "r", "LDS"],
    );
    for f in [8, 4] {
        let (p, train, queries, params) = s.prepared(f, 1, 64)?;
        let lit = p.params_literal(&params)?;
        p.stage1(&lit, &train, Stage1Options::default())?;
        let qg = p.query_grads(&lit, &queries)?;
        let actuals = LdsActuals::get(&p, &lds_protocol(), &train, &queries)?;
        let d_total = p.cfg.tier.spec().total_proj_dim(f);

        // r = 0: GradDot (identity curvature limit)
        let mut gd = build_store_scorer(&p, Method::GradDot)?;
        let rep = gd.score(&qg)?;
        table.row(vec![
            f.to_string(),
            d_total.to_string(),
            "0 (GradDot)".into(),
            fmt_pm(Some(actuals.lds(rep.scores()))),
        ]);

        for r in [8, 32, 128, 384] {
            // curvature from the DENSE store: this panel isolates the
            // truncated-SVD approximation, factorization unused
            let set = lorif::store::ShardSet::open(&p.dense_base())?;
            let curv = TruncatedCurvature::build(
                &set, r, p.cfg.rsvd_oversample, p.cfg.rsvd_power_iters,
                p.cfg.lambda_factor, p.cfg.seed,
            )?;
            let mut scorer =
                DenseWoodburyScorer::new(lorif::store::ShardSet::open(&p.dense_base())?, curv);
            let rep = scorer.score(&qg)?;
            table.row(vec![
                f.to_string(),
                d_total.to_string(),
                r.to_string(),
                fmt_pm(Some(actuals.lds(rep.scores()))),
            ]);
        }

        // full-rank baseline (dense Cholesky = LoGRA)
        let mut logra = build_store_scorer(&p, Method::Logra)?;
        let rep = logra.score(&qg)?;
        table.row(vec![
            f.to_string(),
            d_total.to_string(),
            "full (LoGRA)".into(),
            fmt_pm(Some(actuals.lds(rep.scores()))),
        ]);
    }
    table.print();
    table.save("fig2b")?;
    Ok(())
}

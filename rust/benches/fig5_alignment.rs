//! Figure 5: LDS vs tail-patch alignment across method–configuration
//! pairs (small tier, where both metrics are computable).
//!
//! Expected shape: strong positive linear trend across gradient-based
//! methods; RepSim (non-gradient) deviates furthest from the trend line.

use lorif::app::Method;
use lorif::bench_support::{Session, Table};

fn main() -> anyhow::Result<()> {
    let s = Session::new();
    let mut table = Table::new(
        "Fig 5: LDS vs tail-patch per method-config pair (small tier)",
        &["method", "f", "c/r", "LDS", "tail-patch"],
    );
    let mut points: Vec<(f64, f64, String)> = Vec::new();
    let configs: Vec<(Method, usize, usize, usize)> = vec![
        (Method::RepSim, 4, 1, 64),
        (Method::GradDot, 4, 1, 64),
        (Method::GradDot, 2, 1, 64),
        (Method::TrackStar, 4, 1, 64),
        (Method::Logra, 8, 1, 64),
        (Method::Logra, 4, 1, 64),
        (Method::Logra, 2, 1, 64),
        (Method::Lorif, 4, 1, 128),
        (Method::Lorif, 2, 1, 256),
        (Method::Lorif, 2, 4, 384),
    ];
    for (method, f, c, r) in configs {
        let m = s.measure(method, f, c, r, true, true)?;
        let lds = m.lds.unwrap().0;
        let tp = m.tail_patch.unwrap().0;
        points.push((lds, tp, method.name().to_string()));
        table.row(vec![
            method.name().into(),
            f.to_string(),
            format!("c={c} r={r}"),
            format!("{lds:.4}"),
            format!("{tp:.3}"),
        ]);
    }
    table.print();

    // linear fit + per-method residuals (RepSim should deviate most)
    let grad_pts: Vec<&(f64, f64, String)> =
        points.iter().filter(|p| p.2 != "repsim").collect();
    let n = grad_pts.len() as f64;
    let mx = grad_pts.iter().map(|p| p.0).sum::<f64>() / n;
    let my = grad_pts.iter().map(|p| p.1).sum::<f64>() / n;
    let sxy: f64 = grad_pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let sxx: f64 = grad_pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    let slope = sxy / sxx.max(1e-12);
    let icept = my - slope * mx;
    let corr = {
        let syy: f64 = grad_pts.iter().map(|p| (p.1 - my) * (p.1 - my)).sum();
        sxy / (sxx.sqrt() * syy.sqrt()).max(1e-12)
    };
    println!("\nlinear fit over gradient-based methods: tail-patch = {slope:.2} * LDS + {icept:.3} (pearson r = {corr:.3})");
    for (lds, tp, name) in &points {
        let resid = tp - (slope * lds + icept);
        println!("  {name:10} residual {resid:+.3}{}", if name == "repsim" { "  <-- non-gradient" } else { "" });
    }
    table.save("fig5")?;
    Ok(())
}

//! Table 14: textual relevance vs behavioral influence measure different
//! properties (medium tier).
//!
//! Expected shape: RepSim's judge relevance beats LoGRA's (it retrieves
//! textually plausible examples) but its tail-patch is far lower (those
//! examples don't move the model); LoRIF improves both axes.

use lorif::app::{build_repsim_scorer, build_store_scorer, ensure_embeddings, Method};
use lorif::attribution::Scorer;
use lorif::bench_support::{tailpatch_protocol, Session, Table};
use lorif::eval::{judge, tail_patch, tail_patch_mean};
use lorif::index::Stage1Options;
use lorif::model::spec::Tier;

fn main() -> anyhow::Result<()> {
    let s = Session::with_tier(Tier::Medium);
    let (f_logra, f_lorif) = (8, 4);
    let (p, train, queries, params) = s.prepared(f_logra, 1, 64)?;
    let lit = p.params_literal(&params)?;
    p.stage1(&lit, &train, Stage1Options::default())?;
    let tm = p.topic_model();
    let proto = tailpatch_protocol();

    let mut table = Table::new(
        "Table 14: judge relevance vs tail-patch (medium tier)",
        &["method", "judge relevance", "tail-patch"],
    );

    let mut eval_top = |name: &str,
                        topk: Vec<Vec<usize>>|
     -> anyhow::Result<()> {
        let top1: Vec<usize> = topk.iter().map(|t| t[0]).collect();
        let jj = judge::judge_top1(&tm, &queries, &train, &top1);
        let tp = tail_patch(&p, &params, &train, &queries, &topk, proto)?;
        let (tp_mean, tp_ci) = tail_patch_mean(&tp);
        table.row(vec![
            name.into(),
            format!("{:.2}", jj.avg_score),
            format!("{tp_mean:.3} ± {tp_ci:.3}"),
        ]);
        Ok(())
    };

    // RepSim
    ensure_embeddings(&p, &lit, &train)?;
    let mut repsim = build_repsim_scorer(&p, &lit, &queries)?;
    let qg = p.query_grads(&lit, &queries)?;
    eval_top("RepSim", repsim.score(&qg)?.topk(proto.k))?;

    // LoGRA at its storage-feasible f
    let mut logra = build_store_scorer(&p, Method::Logra)?;
    eval_top("LoGRA", logra.score(&qg)?.topk(proto.k))?;

    // LoRIF at larger D
    let (p2, _, _, _) = s.prepared(f_lorif, 1, 128)?;
    p2.stage1(&lit, &train, Stage1Options { write_dense: false, ..Default::default() })?;
    let qg2 = p2.query_grads(&lit, &queries)?;
    let mut lorif = build_store_scorer(&p2, Method::Lorif)?;
    eval_top("LoRIF", lorif.score(&qg2)?.topk(proto.k))?;

    table.print();
    table.save("tbl14")?;
    Ok(())
}

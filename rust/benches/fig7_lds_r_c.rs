//! Figure 7: LDS vs truncation rank r *with* rank-c factorization —
//! confirming the truncated SVD stays effective when combined with
//! low-rank gradient storage.
//!
//! Expected shape: LDS saturates at r << D for every (D, c) curve,
//! earliest for small c.

use lorif::attribution::Scorer;
use lorif::bench_support::{fmt_pm, lds_protocol, Session, Table};
use lorif::curvature::TruncatedCurvature;
use lorif::eval::LdsActuals;
use lorif::index::Stage1Options;

fn main() -> anyhow::Result<()> {
    let s = Session::new();
    let mut table = Table::new(
        "Fig 7: LDS vs r with rank-c factorization (small tier)",
        &["f", "c", "r", "LDS"],
    );
    for (f, c) in [(4usize, 1usize), (2, 1)] {
        let (p, train, queries, params) = s.prepared(f, c, 64)?;
        let lit = p.params_literal(&params)?;
        p.stage1(&lit, &train, Stage1Options { write_dense: false, ..Default::default() })?;
        let qg = p.query_grads(&lit, &queries)?;
        let actuals = LdsActuals::get(&p, &lds_protocol(), &train, &queries)?;
        for r in [8, 32, 128, 384] {
            let set = lorif::store::ShardSet::open(&p.factored_base())?;
            let curv = TruncatedCurvature::build(
                &set, r, p.cfg.rsvd_oversample, p.cfg.rsvd_power_iters,
                p.cfg.lambda_factor, p.cfg.seed,
            )?;
            let mut scorer = lorif::attribution::LorifScorer::new(
                lorif::store::ShardSet::open(&p.factored_base())?,
                curv,
            );
            let rep = scorer.score(&qg)?;
            table.row(vec![
                f.to_string(),
                c.to_string(),
                r.to_string(),
                fmt_pm(Some(actuals.lds(rep.scores()))),
            ]);
        }
    }
    table.print();
    table.save("fig7")?;
    Ok(())
}

//! Property-based tests on coordinator invariants.
//!
//! The offline vendor set has no proptest, so this file uses an in-repo
//! randomized-property harness: each property runs over many seeded
//! random cases; on failure it reports the seed (re-run with
//! `LORIF_PROP_SEED=<seed>` to reproduce a single case).  No shrinking —
//! cases are kept small enough to debug directly.

use lorif::linalg::{eigh, qr, rsvd, Chol, Mat};
use lorif::store::{StoreKind, StoreMeta};
use lorif::util::bf16;
use lorif::util::json::Value;
use lorif::util::prng::Rng;

const CASES: usize = 40;

fn for_each_case(name: &str, mut f: impl FnMut(u64, &mut Rng)) {
    if let Ok(s) = std::env::var("LORIF_PROP_SEED") {
        let seed: u64 = s.parse().unwrap();
        let mut rng = Rng::labeled(seed, name);
        f(seed, &mut rng);
        return;
    }
    for seed in 0..CASES as u64 {
        let mut rng = Rng::labeled(seed, name);
        f(seed, &mut rng);
    }
}

// ---------------------------------------------------------------------------
// storage invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_store_layout_bijective() {
    // layer_span offsets tile the record exactly, for random layer sets
    for_each_case("store-layout", |seed, rng| {
        let n_layers = 1 + rng.below(6);
        let layers: Vec<(usize, usize)> =
            (0..n_layers).map(|_| (1 + rng.below(64), 1 + rng.below(64))).collect();
        let c = 1 + rng.below(4);
        for kind in [StoreKind::Dense, StoreKind::Factored] {
            let meta = StoreMeta {
                kind,
                tier: "small".into(),
                f: 4,
                c,
                layers: layers.clone(),
                n_examples: 7,
            };
            let mut end = 0;
            for l in 0..n_layers {
                let (off, len) = meta.layer_span(l);
                assert_eq!(off, end, "seed {seed}: layer {l} not contiguous");
                end = off + len * 2;
            }
            assert_eq!(end, meta.bytes_per_example(), "seed {seed}");
        }
    });
}

#[test]
fn prop_bf16_roundtrip_error_bound() {
    // |decode(encode(x)) - x| <= |x| * 2^-8 for all finite x
    for_each_case("bf16", |seed, rng| {
        for _ in 0..100 {
            let x = (rng.normal() * 10f64.powi(rng.below(9) as i32 - 4)) as f32;
            let y = bf16::bf16_to_f32(bf16::f32_to_bf16(x));
            assert!(
                (y - x).abs() <= x.abs() / 256.0 + f32::MIN_POSITIVE,
                "seed {seed}: {x} -> {y}"
            );
        }
    });
}

#[test]
fn prop_factorization_compression_ratio() {
    // factored storage < dense storage whenever c < min(d1,d2)/2, and the
    // ratio matches the paper's min(d1,d2)/2c rule within 2x
    for_each_case("compression", |seed, rng| {
        let d1 = 4 + rng.below(60);
        let d2 = 4 + rng.below(60);
        let c = 1 + rng.below(d1.min(d2) / 2);
        let dense = d1 * d2;
        let fact = c * (d1 + d2);
        if c <= d1.min(d2) / 2 {
            let ratio = dense as f64 / fact as f64;
            let paper = d1.min(d2) as f64 / (2.0 * c as f64);
            assert!(
                ratio >= paper / 2.0 && ratio <= paper * 2.5,
                "seed {seed}: ratio {ratio} vs paper-rule {paper} (d1={d1} d2={d2} c={c})"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// linalg invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_qr_orthogonality_and_reconstruction() {
    for_each_case("qr", |seed, rng| {
        let m = 5 + rng.below(40);
        let n = 1 + rng.below(m.min(12));
        let a = Mat::random_normal(m, n, 1.0, rng);
        let (q, r) = qr::qr_thin(&a);
        let qtq = q.matmul_tn(&q);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.at(i, j) - want).abs() < 1e-3, "seed {seed}");
            }
        }
        let rec = q.matmul(&r);
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "seed {seed}");
        }
    });
}

#[test]
fn prop_cholesky_solve_residual() {
    for_each_case("chol", |seed, rng| {
        let n = 2 + rng.below(24);
        let a = Mat::random_normal(n, n, 1.0, rng);
        let mut spd = a.matmul_tn(&a);
        for i in 0..n {
            *spd.at_mut(i, i) += 1.0;
        }
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let x = Chol::factor(&spd).unwrap().solve(&b);
        let ax = spd.matvec(&x);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-2 * (1.0 + b[i].abs()), "seed {seed}");
        }
    });
}

#[test]
fn prop_eigh_trace_and_psd() {
    // trace(A) == sum of eigenvalues; A PSD -> eigenvalues >= 0
    for_each_case("eigh", |seed, rng| {
        let n = 2 + rng.below(16);
        let a = Mat::random_normal(n, n, 1.0, rng);
        let psd = a.matmul_tn(&a);
        let (vals, _) = eigh::eigh(&psd);
        let trace: f32 = (0..n).map(|i| psd.at(i, i)).sum();
        let sum: f32 = vals.iter().sum();
        assert!((trace - sum).abs() < 1e-2 * (1.0 + trace.abs()), "seed {seed}");
        assert!(vals.iter().all(|&v| v > -1e-3), "seed {seed}: {vals:?}");
    });
}

#[test]
fn prop_rsvd_eckart_young_within_slack() {
    // randomized SVD reconstruction error is within 1.6x of the optimal
    // rank-r error (standard rSVD guarantee with oversampling + power its)
    for_each_case("rsvd", |seed, rng| {
        let n = 12 + rng.below(24);
        let d = 8 + rng.below(16);
        let a = Mat::random_normal(n, d, 1.0, rng);
        let r = 1 + rng.below(d.min(n) / 2);
        let mut src = rsvd::MatSource { mat: &a, chunk: 7 };
        let svd = rsvd::rsvd(&mut src, r, 6, 2, seed).unwrap();
        let rec = svd.train_proj.matmul_nt(&svd.v);
        let mut err2 = 0.0f32;
        for (x, y) in rec.data.iter().zip(&a.data) {
            err2 += (x - y) * (x - y);
        }
        let (_, s, _) = eigh::svd_small(&a);
        let opt2: f32 = s[r..].iter().map(|x| x * x).sum();
        assert!(
            err2.sqrt() <= opt2.sqrt() * 1.6 + 1e-3,
            "seed {seed}: err {} vs opt {} (r={r})",
            err2.sqrt(),
            opt2.sqrt()
        );
    });
}

#[test]
fn prop_woodbury_identity_exact() {
    // (V S^2 V^T + lam I)^{-1} == I/lam - V diag(w) V^T for orthonormal V
    for_each_case("woodbury", |seed, rng| {
        let d = 4 + rng.below(12);
        let r = 1 + rng.below(d / 2 + 1);
        let raw = Mat::random_normal(d, r, 1.0, rng);
        let v = qr::orthonormalize(&raw);
        let sigma: Vec<f32> = (0..r).map(|_| 0.2 + rng.uniform() as f32 * 3.0).collect();
        let lam = 0.1 + rng.uniform() as f32;
        // H = V S^2 V^T + lam I
        let mut h = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..r {
                    s += v.at(i, k) * sigma[k] * sigma[k] * v.at(j, k);
                }
                *h.at_mut(i, j) = s + if i == j { lam } else { 0.0 };
            }
        }
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let direct = Chol::factor(&h).unwrap().solve(&x);
        // woodbury route
        let w: Vec<f32> =
            sigma.iter().map(|&s| s * s / (lam * (lam + s * s))).collect();
        let vx = v.matvec_t(&x);
        let mut wood: Vec<f32> = x.iter().map(|&xi| xi / lam).collect();
        for i in 0..d {
            let mut corr = 0.0;
            for k in 0..r {
                corr += v.at(i, k) * w[k] * vx[k];
            }
            wood[i] -= corr;
        }
        for i in 0..d {
            assert!(
                (direct[i] - wood[i]).abs() < 2e-3 * (1.0 + direct[i].abs()),
                "seed {seed}: {} vs {}",
                direct[i],
                wood[i]
            );
        }
    });
}

// ---------------------------------------------------------------------------
// coordinator invariants (routing / batching / state)
// ---------------------------------------------------------------------------

#[test]
fn prop_topk_sorted_and_within_range() {
    use lorif::attribution::ScoreReport;
    use lorif::util::timer::PhaseTimer;
    for_each_case("topk", |seed, rng| {
        let nq = 1 + rng.below(5);
        let n = 5 + rng.below(200);
        let scores = Mat::random_normal(nq, n, 1.0, rng);
        let rep = ScoreReport { scores, timer: PhaseTimer::new(), bytes_read: 0 };
        let k = 1 + rng.below(n);
        let topk = rep.topk(k);
        for (q, top) in topk.iter().enumerate() {
            assert_eq!(top.len(), k.min(n), "seed {seed}");
            for w in top.windows(2) {
                assert!(
                    rep.scores.at(q, w[0]) >= rep.scores.at(q, w[1]),
                    "seed {seed}: not sorted"
                );
            }
            let max = (0..n).map(|i| rep.scores.at(q, i)).fold(f32::MIN, f32::max);
            assert_eq!(rep.scores.at(q, top[0]), max, "seed {seed}: wrong argmax");
        }
    });
}

#[test]
fn prop_dataset_batch_padding_stable() {
    use lorif::corpus::{Dataset, TopicModel};
    for_each_case("batch-pad", |seed, rng| {
        let tm = TopicModel::new(4, seed);
        let ds = Dataset::generate(&tm, 10 + rng.below(30), 16, seed ^ 1);
        let batch = 4 + rng.below(12);
        let take = 1 + rng.below(batch);
        let idx: Vec<usize> = (0..take).map(|_| rng.below(ds.len())).collect();
        let b = ds.batch(&idx, batch);
        assert_eq!(b.len(), batch * 16, "seed {seed}");
        // padding repeats the last valid example
        let last = idx[idx.len() - 1];
        for pad in take..batch {
            assert_eq!(&b[pad * 16..(pad + 1) * 16], ds.example(last), "seed {seed}");
        }
    });
}

#[test]
fn prop_spearman_bounds_and_symmetry() {
    use lorif::eval::spearman::spearman;
    for_each_case("spearman", |seed, rng| {
        let n = 3 + rng.below(50);
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let r = spearman(&a, &b);
        assert!((-1.0..=1.0).contains(&r), "seed {seed}: {r}");
        assert!((spearman(&b, &a) - r).abs() < 1e-12, "seed {seed}: asymmetric");
        assert!((spearman(&a, &a) - 1.0).abs() < 1e-9, "seed {seed}");
    });
}

#[test]
fn prop_json_roundtrip_arbitrary() {
    // random JSON value -> to_string -> parse == identity
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => Value::Num((rng.normal() * 100.0 * 64.0).round() / 64.0),
            3 => {
                let n = rng.below(8);
                Value::Str((0..n).map(|_| "ab\"\\\nπ8".chars().nth(rng.below(7)).unwrap()).collect())
            }
            4 => Value::Arr((0..rng.below(4)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for_each_case("json", |seed, rng| {
        let v = random_value(rng, 3);
        let text = v.to_string();
        let back = Value::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(v, back, "seed {seed}: {text}");
    });
}

#[test]
fn prop_reconstruct_row_rank_additivity() {
    // reconstruct(u, v, c) == sum_k reconstruct(u_k, v_k, 1)
    use lorif::curvature::reconstruct_row;
    for_each_case("reconstruct", |seed, rng| {
        let d1 = 2 + rng.below(10);
        let d2 = 2 + rng.below(10);
        let c = 1 + rng.below(4);
        let u: Vec<f32> = (0..d1 * c).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..d2 * c).map(|_| rng.normal() as f32).collect();
        let mut full = vec![0.0f32; d1 * d2];
        reconstruct_row(&u, &v, d1, d2, c, &mut full);
        let mut acc = vec![0.0f32; d1 * d2];
        for k in 0..c {
            let uk: Vec<f32> = (0..d1).map(|a| u[a * c + k]).collect();
            let vk: Vec<f32> = (0..d2).map(|b| v[b * c + k]).collect();
            let mut one = vec![0.0f32; d1 * d2];
            reconstruct_row(&uk, &vk, d1, d2, 1, &mut one);
            for (a, o) in acc.iter_mut().zip(&one) {
                *a += o;
            }
        }
        for (x, y) in full.iter().zip(&acc) {
            assert!((x - y).abs() < 1e-4, "seed {seed}");
        }
    });
}

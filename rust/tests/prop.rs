//! Property-based tests on coordinator invariants.
//!
//! The offline vendor set has no proptest, so this file uses an in-repo
//! randomized-property harness: each property runs over many seeded
//! random cases; on failure it reports the seed (re-run with
//! `LORIF_PROP_SEED=<seed>` to reproduce a single case).  No shrinking —
//! cases are kept small enough to debug directly.
//!
//! `LORIF_PROP_CASES=<n>` raises the case count per property (the CI
//! nightly hardening job runs with a multiple of the default).

use lorif::linalg::{eigh, qr, rsvd, Chol, Mat};
use lorif::runtime::{ExtractBatch, LayerGrads};
use lorif::store::{ShardSet, ShardedWriter, StoreKind, StoreMeta, StoreReader, StoreWriter};
use lorif::util::bf16;
use lorif::util::json::Value;
use lorif::util::prng::Rng;

const CASES: usize = 40;

fn case_count() -> usize {
    std::env::var("LORIF_PROP_CASES")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(CASES)
}

fn for_each_case(name: &str, mut f: impl FnMut(u64, &mut Rng)) {
    match std::env::var("LORIF_PROP_SEED") {
        Ok(s) if !s.trim().is_empty() => {
            let seed: u64 = s.trim().parse().expect("LORIF_PROP_SEED must be a u64");
            let mut rng = Rng::labeled(seed, name);
            f(seed, &mut rng);
        }
        _ => {
            for seed in 0..case_count() as u64 {
                let mut rng = Rng::labeled(seed, name);
                f(seed, &mut rng);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// storage invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_store_layout_bijective() {
    // layer_span offsets tile the record exactly, for random layer sets
    // and every record codec (the encoded byte length of each layer is
    // the codec's per-segment encoded_len)
    use lorif::store::{Codec, CodecId};
    for_each_case("store-layout", |seed, rng| {
        let n_layers = 1 + rng.below(6);
        let layers: Vec<(usize, usize)> =
            (0..n_layers).map(|_| (1 + rng.below(64), 1 + rng.below(64))).collect();
        let c = 1 + rng.below(4);
        for codec in CodecId::ALL {
            for kind in [StoreKind::Dense, StoreKind::Factored] {
                let meta = StoreMeta {
                    kind,
                    tier: "small".into(),
                    f: 4,
                    c,
                    layers: layers.clone(),
                    n_examples: 7,
                    shards: None,
                    summary_chunk: None,
                    codec,
                };
                let enc = codec.get();
                let mut end = 0;
                for l in 0..n_layers {
                    let (off, flen) = meta.layer_span(l).unwrap();
                    assert_eq!(off, end, "seed {seed}: {codec:?} layer {l} not contiguous");
                    let (d1, d2) = layers[l];
                    let (want_flen, blen) = match kind {
                        StoreKind::Dense => (d1 * d2, enc.encoded_len(d1 * d2)),
                        StoreKind::Factored => (
                            c * (d1 + d2),
                            enc.encoded_len(c * d1) + enc.encoded_len(c * d2),
                        ),
                    };
                    assert_eq!(flen, want_flen, "seed {seed}: {codec:?}");
                    end = off + blen;
                }
                assert_eq!(end, meta.bytes_per_example(), "seed {seed}: {codec:?}");
                // one past the end is an error, not a panic
                assert!(meta.layer_span(n_layers).is_err(), "seed {seed}");
            }
        }
    });
}

#[test]
fn prop_bf16_roundtrip_error_bound() {
    // |decode(encode(x)) - x| <= |x| * 2^-8 for all finite x
    for_each_case("bf16", |seed, rng| {
        for _ in 0..100 {
            let x = (rng.normal() * 10f64.powi(rng.below(9) as i32 - 4)) as f32;
            let y = bf16::bf16_to_f32(bf16::f32_to_bf16(x));
            assert!(
                (y - x).abs() <= x.abs() / 256.0 + f32::MIN_POSITIVE,
                "seed {seed}: {x} -> {y}"
            );
        }
    });
}

#[test]
fn prop_factorization_compression_ratio() {
    // factored storage < dense storage whenever c < min(d1,d2)/2, and the
    // ratio matches the paper's min(d1,d2)/2c rule within 2x
    for_each_case("compression", |seed, rng| {
        let d1 = 4 + rng.below(60);
        let d2 = 4 + rng.below(60);
        let c = 1 + rng.below(d1.min(d2) / 2);
        let dense = d1 * d2;
        let fact = c * (d1 + d2);
        if c <= d1.min(d2) / 2 {
            let ratio = dense as f64 / fact as f64;
            let paper = d1.min(d2) as f64 / (2.0 * c as f64);
            assert!(
                ratio >= paper / 2.0 && ratio <= paper * 2.5,
                "seed {seed}: ratio {ratio} vs paper-rule {paper} (d1={d1} d2={d2} c={c})"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// linalg invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_qr_orthogonality_and_reconstruction() {
    for_each_case("qr", |seed, rng| {
        let m = 5 + rng.below(40);
        let n = 1 + rng.below(m.min(12));
        let a = Mat::random_normal(m, n, 1.0, rng);
        let (q, r) = qr::qr_thin(&a);
        let qtq = q.matmul_tn(&q);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.at(i, j) - want).abs() < 1e-3, "seed {seed}");
            }
        }
        let rec = q.matmul(&r);
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "seed {seed}");
        }
    });
}

#[test]
fn prop_cholesky_solve_residual() {
    for_each_case("chol", |seed, rng| {
        let n = 2 + rng.below(24);
        let a = Mat::random_normal(n, n, 1.0, rng);
        let mut spd = a.matmul_tn(&a);
        for i in 0..n {
            *spd.at_mut(i, i) += 1.0;
        }
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let x = Chol::factor(&spd).unwrap().solve(&b);
        let ax = spd.matvec(&x);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-2 * (1.0 + b[i].abs()), "seed {seed}");
        }
    });
}

#[test]
fn prop_eigh_trace_and_psd() {
    // trace(A) == sum of eigenvalues; A PSD -> eigenvalues >= 0
    for_each_case("eigh", |seed, rng| {
        let n = 2 + rng.below(16);
        let a = Mat::random_normal(n, n, 1.0, rng);
        let psd = a.matmul_tn(&a);
        let (vals, _) = eigh::eigh(&psd);
        let trace: f32 = (0..n).map(|i| psd.at(i, i)).sum();
        let sum: f32 = vals.iter().sum();
        assert!((trace - sum).abs() < 1e-2 * (1.0 + trace.abs()), "seed {seed}");
        assert!(vals.iter().all(|&v| v > -1e-3), "seed {seed}: {vals:?}");
    });
}

#[test]
fn prop_rsvd_eckart_young_within_slack() {
    // randomized SVD reconstruction error is within 1.6x of the optimal
    // rank-r error (standard rSVD guarantee with oversampling + power its)
    for_each_case("rsvd", |seed, rng| {
        let n = 12 + rng.below(24);
        let d = 8 + rng.below(16);
        let a = Mat::random_normal(n, d, 1.0, rng);
        let r = 1 + rng.below(d.min(n) / 2);
        let mut src = rsvd::MatSource { mat: &a, chunk: 7 };
        let svd = rsvd::rsvd(&mut src, r, 6, 2, seed).unwrap();
        let rec = svd.train_proj.matmul_nt(&svd.v);
        let mut err2 = 0.0f32;
        for (x, y) in rec.data.iter().zip(&a.data) {
            err2 += (x - y) * (x - y);
        }
        let (_, s, _) = eigh::svd_small(&a);
        let opt2: f32 = s[r..].iter().map(|x| x * x).sum();
        assert!(
            err2.sqrt() <= opt2.sqrt() * 1.6 + 1e-3,
            "seed {seed}: err {} vs opt {} (r={r})",
            err2.sqrt(),
            opt2.sqrt()
        );
    });
}

#[test]
fn prop_woodbury_identity_exact() {
    // (V S^2 V^T + lam I)^{-1} == I/lam - V diag(w) V^T for orthonormal V
    for_each_case("woodbury", |seed, rng| {
        let d = 4 + rng.below(12);
        let r = 1 + rng.below(d / 2 + 1);
        let raw = Mat::random_normal(d, r, 1.0, rng);
        let v = qr::orthonormalize(&raw);
        let sigma: Vec<f32> = (0..r).map(|_| 0.2 + rng.uniform() as f32 * 3.0).collect();
        let lam = 0.1 + rng.uniform() as f32;
        // H = V S^2 V^T + lam I
        let mut h = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..r {
                    s += v.at(i, k) * sigma[k] * sigma[k] * v.at(j, k);
                }
                *h.at_mut(i, j) = s + if i == j { lam } else { 0.0 };
            }
        }
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let direct = Chol::factor(&h).unwrap().solve(&x);
        // woodbury route
        let w: Vec<f32> =
            sigma.iter().map(|&s| s * s / (lam * (lam + s * s))).collect();
        let vx = v.matvec_t(&x);
        let mut wood: Vec<f32> = x.iter().map(|&xi| xi / lam).collect();
        for i in 0..d {
            let mut corr = 0.0;
            for k in 0..r {
                corr += v.at(i, k) * w[k] * vx[k];
            }
            wood[i] -= corr;
        }
        for i in 0..d {
            assert!(
                (direct[i] - wood[i]).abs() < 2e-3 * (1.0 + direct[i].abs()),
                "seed {seed}: {} vs {}",
                direct[i],
                wood[i]
            );
        }
    });
}

// ---------------------------------------------------------------------------
// coordinator invariants (routing / batching / state)
// ---------------------------------------------------------------------------

#[test]
fn prop_topk_sorted_and_within_range() {
    use lorif::attribution::ScoreReport;
    use lorif::util::timer::PhaseTimer;
    for_each_case("topk", |seed, rng| {
        let nq = 1 + rng.below(5);
        let n = 5 + rng.below(200);
        let scores = Mat::random_normal(nq, n, 1.0, rng);
        let rep = ScoreReport::full(scores, PhaseTimer::new(), 0);
        let k = 1 + rng.below(n);
        let topk = rep.topk(k);
        for (q, top) in topk.iter().enumerate() {
            assert_eq!(top.len(), k.min(n), "seed {seed}");
            for w in top.windows(2) {
                assert!(
                    rep.scores().at(q, w[0]) >= rep.scores().at(q, w[1]),
                    "seed {seed}: not sorted"
                );
            }
            let max = (0..n).map(|i| rep.scores().at(q, i)).fold(f32::MIN, f32::max);
            assert_eq!(rep.scores().at(q, top[0]), max, "seed {seed}: wrong argmax");
        }
    });
}

#[test]
fn prop_dataset_batch_padding_stable() {
    use lorif::corpus::{Dataset, TopicModel};
    for_each_case("batch-pad", |seed, rng| {
        let tm = TopicModel::new(4, seed);
        let ds = Dataset::generate(&tm, 10 + rng.below(30), 16, seed ^ 1);
        let batch = 4 + rng.below(12);
        let take = 1 + rng.below(batch);
        let idx: Vec<usize> = (0..take).map(|_| rng.below(ds.len())).collect();
        let b = ds.batch(&idx, batch);
        assert_eq!(b.len(), batch * 16, "seed {seed}");
        // padding repeats the last valid example
        let last = idx[idx.len() - 1];
        for pad in take..batch {
            assert_eq!(&b[pad * 16..(pad + 1) * 16], ds.example(last), "seed {seed}");
        }
    });
}

#[test]
fn prop_spearman_bounds_and_symmetry() {
    use lorif::eval::spearman::spearman;
    for_each_case("spearman", |seed, rng| {
        let n = 3 + rng.below(50);
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let r = spearman(&a, &b);
        assert!((-1.0..=1.0).contains(&r), "seed {seed}: {r}");
        assert!((spearman(&b, &a) - r).abs() < 1e-12, "seed {seed}: asymmetric");
        assert!((spearman(&a, &a) - 1.0).abs() < 1e-9, "seed {seed}");
    });
}

#[test]
fn prop_json_roundtrip_arbitrary() {
    // random JSON value -> to_string -> parse == identity
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => Value::Num((rng.normal() * 100.0 * 64.0).round() / 64.0),
            3 => {
                let n = rng.below(8);
                Value::Str((0..n).map(|_| "ab\"\\\nπ8".chars().nth(rng.below(7)).unwrap()).collect())
            }
            4 => Value::Arr((0..rng.below(4)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for_each_case("json", |seed, rng| {
        let v = random_value(rng, 3);
        let text = v.to_string();
        let back = Value::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(v, back, "seed {seed}: {text}");
    });
}

// ---------------------------------------------------------------------------
// sharded-store invariants
// ---------------------------------------------------------------------------

fn prop_tmp_base(prefix: &str, seed: u64) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("lorif_prop_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{prefix}_{seed}"))
}

/// Random per-layer train data for `n` examples.
fn random_layers(n: usize, dims: &[(usize, usize)], c: usize, rng: &mut Rng) -> Vec<LayerGrads> {
    dims.iter()
        .map(|&(d1, d2)| LayerGrads {
            g: Mat::random_normal(n, d1 * d2, 1.0, rng),
            u: Mat::random_normal(n, d1 * c, 1.0, rng),
            v: Mat::random_normal(n, d2 * c, 1.0, rng),
        })
        .collect()
}

/// Append `data` in batches of random (non-divisor) sizes.
fn append_in_batches(
    data: &[LayerGrads],
    n: usize,
    rng: &mut Rng,
    mut push: impl FnMut(&ExtractBatch),
) {
    let mut at = 0usize;
    while at < n {
        let take = (1 + rng.below(7)).min(n - at);
        let idx: Vec<usize> = (at..at + take).collect();
        let layers: Vec<LayerGrads> = data
            .iter()
            .map(|lg| LayerGrads {
                g: lg.g.select_rows(&idx),
                u: lg.u.select_rows(&idx),
                v: lg.v.select_rows(&idx),
            })
            .collect();
        push(&ExtractBatch { losses: vec![0.0; take], layers, valid: take });
        at += take;
    }
}

#[test]
fn prop_store_roundtrip_v1_and_v2() {
    // writer -> reader roundtrip across Dense/Factored kinds, random
    // layer shapes, non-divisor batch sizes, and both layouts: every
    // value read back equals the bf16 quantization of what was written,
    // and the v2 sharded store holds exactly the v1 records.
    for_each_case("store-roundtrip", |seed, rng| {
        let n_layers = 1 + rng.below(3);
        let dims: Vec<(usize, usize)> =
            (0..n_layers).map(|_| (1 + rng.below(9), 1 + rng.below(9))).collect();
        let c = 1 + rng.below(3.min(dims.iter().map(|&(a, b)| a.min(b)).min().unwrap()));
        let n = 3 + rng.below(40);
        let shards = 1 + rng.below(5);
        let kind = if rng.below(2) == 0 { StoreKind::Dense } else { StoreKind::Factored };
        let meta = StoreMeta {
            kind,
            tier: "small".into(),
            f: 4,
            c,
            layers: dims.clone(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: lorif::store::CodecId::Bf16,
        };
        let data = random_layers(n, &dims, c, rng);

        let v1_base = prop_tmp_base("rt_v1", seed);
        let mut w = StoreWriter::create(&v1_base, meta.clone()).unwrap();
        append_in_batches(&data, n, &mut Rng::labeled(seed, "batches"), |b| {
            w.append(b).unwrap()
        });
        let v1_meta = w.finalize().unwrap();
        assert_eq!(v1_meta.n_examples, n, "seed {seed}");
        assert_eq!(v1_meta.shards, None, "seed {seed}");

        let v2_base = prop_tmp_base("rt_v2", seed);
        let mut w = ShardedWriter::create(&v2_base, meta, shards, n).unwrap();
        append_in_batches(&data, n, &mut Rng::labeled(seed, "batches"), |b| {
            w.append(b).unwrap()
        });
        let v2_meta = w.finalize().unwrap();
        assert_eq!(v2_meta.n_examples, n, "seed {seed}");
        let counts = v2_meta.shards.clone().unwrap();
        assert!(counts.len() <= shards, "seed {seed}");
        assert_eq!(counts.iter().sum::<usize>(), n, "seed {seed}");

        // reference: bf16-quantized originals
        let quant = |m: &Mat, row: usize| -> Vec<f32> {
            m.row(row).iter().map(|&x| bf16::bf16_to_f32(bf16::f32_to_bf16(x))).collect()
        };
        let chunk_size = 1 + rng.below(2 * n);
        for base in [&v1_base, &v2_base] {
            let set = ShardSet::open(base).unwrap();
            assert_eq!(set.meta.n_examples, n, "seed {seed}");
            let mut seen = 0usize;
            set.stream(chunk_size, false, |chunk| {
                assert_eq!(chunk.start, seen, "seed {seed}: chunks in order");
                for (l, layer) in chunk.layers.iter().enumerate() {
                    for ex in 0..chunk.count {
                        let global = chunk.start + ex;
                        match kind {
                            StoreKind::Dense => {
                                assert_eq!(
                                    layer.dense().row(ex),
                                    &quant(&data[l].g, global)[..],
                                    "seed {seed}: layer {l} example {global}"
                                );
                            }
                            StoreKind::Factored => {
                                let (u, v) = layer.factors();
                                assert_eq!(
                                    u.row(ex),
                                    &quant(&data[l].u, global)[..],
                                    "seed {seed}: u layer {l} example {global}"
                                );
                                assert_eq!(
                                    v.row(ex),
                                    &quant(&data[l].v, global)[..],
                                    "seed {seed}: v layer {l} example {global}"
                                );
                            }
                        }
                    }
                }
                seen += chunk.count;
                Ok(())
            })
            .unwrap();
            assert_eq!(seen, n, "seed {seed}");
        }

        // v1-file/v2-manifest compatibility: the plain v1 reader and the
        // shard-set view of the v1 store agree record-for-record
        let direct = StoreReader::open(&v1_base).unwrap();
        let via_set = ShardSet::open(&v1_base).unwrap();
        let a = direct.read_range(0, n).unwrap();
        let b = via_set.read_range(0, n).unwrap();
        for l in 0..n_layers {
            match kind {
                StoreKind::Dense => {
                    assert_eq!(a.layers[l].dense().data, b.layers[l].dense().data);
                }
                StoreKind::Factored => {
                    assert_eq!(a.layers[l].factors().0.data, b.layers[l].factors().0.data);
                    assert_eq!(a.layers[l].factors().1.data, b.layers[l].factors().1.data);
                }
            }
        }
    });
}

#[test]
fn prop_sharded_scoring_equals_monolithic() {
    // For random (n_examples, shards, layers, c): scoring a sharded
    // store on a multi-threaded worker pool equals scoring the
    // monolithic store single-threaded, within bf16-noise-free float
    // tolerance, and the merged top-k equals the global top-k computed
    // from the full score matrix.
    use lorif::attribution::graddot::GradDotScorer;
    use lorif::attribution::{QueryGrads, QueryLayer, Scorer};

    for_each_case("sharded-scoring", |seed, rng| {
        let n_layers = 1 + rng.below(2);
        let dims: Vec<(usize, usize)> =
            (0..n_layers).map(|_| (1 + rng.below(6), 1 + rng.below(6))).collect();
        let n = 8 + rng.below(50);
        let nq = 1 + rng.below(4);
        let shards = 1 + rng.below(5);
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: dims.clone(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: lorif::store::CodecId::Bf16,
        };
        let data = random_layers(n, &dims, 1, rng);
        let batch_layers: Vec<LayerGrads> = data
            .iter()
            .map(|lg| LayerGrads { g: lg.g.clone(), u: lg.u.clone(), v: lg.v.clone() })
            .collect();
        let batch = ExtractBatch { losses: vec![0.0; n], layers: batch_layers, valid: n };

        let mono_base = prop_tmp_base("score_mono", seed);
        let mut w = StoreWriter::create(&mono_base, meta.clone()).unwrap();
        w.append(&batch).unwrap();
        w.finalize().unwrap();
        let shard_base = prop_tmp_base("score_shard", seed);
        let mut w = ShardedWriter::create(&shard_base, meta, shards, n).unwrap();
        w.append(&batch).unwrap();
        w.finalize().unwrap();

        let qlayers: Vec<QueryLayer> = dims
            .iter()
            .map(|&(d1, d2)| QueryLayer {
                g: Mat::random_normal(nq, d1 * d2, 1.0, rng),
                u: Mat::zeros(nq, d1),
                v: Mat::zeros(nq, d2),
            })
            .collect();
        let qg =
            QueryGrads { n_query: nq, c: 1, proj_dims: dims.clone(), layers: qlayers };

        let mut mono = GradDotScorer::new(ShardSet::open(&mono_base).unwrap());
        mono.score_threads = 1;
        mono.chunk_size = 1 + rng.below(n);
        mono.prefetch = rng.below(2) == 0;
        let mut sharded = GradDotScorer::new(ShardSet::open(&shard_base).unwrap());
        sharded.score_threads = 1 + rng.below(4);
        sharded.chunk_size = 1 + rng.below(n);
        sharded.prefetch = rng.below(2) == 0;

        let ra = mono.score(&qg).unwrap();
        let rb = sharded.score(&qg).unwrap();
        assert_eq!(ra.bytes_read, rb.bytes_read, "seed {seed}");
        let scale = ra.scores().data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in ra.scores().data.iter().zip(&rb.scores().data) {
            assert!(
                (a - b).abs() <= 1e-5 * scale.max(1.0),
                "seed {seed}: {a} vs {b}"
            );
        }

        // merged top-k (parallel column-block heaps over the sharded
        // scores) == global top-k from the full monolithic matrix
        let k = 1 + rng.below(n);
        let global = ra.topk(k);
        let merged = lorif::query::parallel::topk(rb.scores(), k, 1 + rng.below(4));
        assert_eq!(merged, global, "seed {seed} (k={k})");
    });
}

#[test]
fn prop_parallel_topk_equals_stable_argsort() {
    use lorif::attribution::ScoreReport;
    use lorif::util::timer::PhaseTimer;
    for_each_case("parallel-topk", |seed, rng| {
        let nq = 1 + rng.below(4);
        let n = 1 + rng.below(300);
        let scores = Mat::random_normal(nq, n, 1.0, rng);
        let k = 1 + rng.below(n + 5); // may exceed n: must clamp
        let threads = 1 + rng.below(4);
        let want =
            ScoreReport::full(scores.clone(), PhaseTimer::new(), 0).topk(k.min(n));
        let got = lorif::query::parallel::topk(&scores, k, threads);
        assert_eq!(got, want, "seed {seed} (n={n} k={k} threads={threads})");
    });
}

#[test]
fn prop_merge_topk_two_level_equals_one_shot() {
    // The distributed exactness argument: a node merges its own shards'
    // heaps, the coordinator merges the node heaps — and that two-level
    // reduction must equal merging ALL shard heaps at once, for ANY
    // grouping of shards onto nodes (including orderings that interleave
    // shard index ranges), with NaN scores and exact-duplicate scores
    // forcing the ascending-index tie-break to decide entries.
    use lorif::query::{merge_topk, TopK};
    for_each_case("merge-topk-two-level", |seed, rng| {
        let nq = 1 + rng.below(3);
        let n_shards = 2 + rng.below(6);
        let k = 1 + rng.below(10);
        // per-shard heaps over disjoint global index ranges, scores
        // drawn from a tiny quantized set so duplicates are common
        let mut start = 0usize;
        let shard_heaps: Vec<Vec<TopK>> = (0..n_shards)
            .map(|_| {
                let count = 1 + rng.below(30);
                let heaps: Vec<TopK> = (0..nq)
                    .map(|_| {
                        let mut h = TopK::new(k);
                        for i in 0..count {
                            let r = rng.below(16);
                            let s =
                                if r == 0 { f32::NAN } else { (r as f32 - 8.0) * 0.5 };
                            h.push(start + i, s);
                        }
                        h
                    })
                    .collect();
                start += count;
                heaps
            })
            .collect();

        // one-shot reference: every shard heap merged in one reduction
        let one_shot = merge_topk(nq, k, shard_heaps.clone());

        // random shard -> node assignment (possibly interleaving index
        // ranges across nodes), then the coordinator-style second level
        let n_nodes = 1 + rng.below(n_shards);
        let mut groups: Vec<Vec<Vec<TopK>>> = vec![Vec::new(); n_nodes];
        for heaps in &shard_heaps {
            groups[rng.below(n_nodes)].push(heaps.clone());
        }
        let node_heaps: Vec<Vec<TopK>> = groups
            .into_iter()
            .filter(|g| !g.is_empty())
            .map(|g| merge_topk(nq, k, g))
            .collect();
        let two_level = merge_topk(nq, k, node_heaps);

        // bit-exact comparison (f64 would erase NaN identity)
        let flat = |heaps: &[TopK]| -> Vec<Vec<(u32, usize)>> {
            heaps
                .iter()
                .map(|h| h.entries().iter().map(|&(s, i)| (s.to_bits(), i)).collect())
                .collect()
        };
        assert_eq!(
            flat(&two_level),
            flat(&one_shot),
            "seed {seed} (nq={nq} shards={n_shards} nodes={n_nodes} k={k})"
        );
    });
}

#[test]
fn prop_shard_boundaries_partition_examples() {
    // ShardedWriter splits N examples into contiguous shards that
    // partition [0, N): sizes sum to N, every shard (except possibly
    // the last) is equally sized, and ShardSet spans are contiguous.
    for_each_case("shard-partition", |seed, rng| {
        let dims = vec![(1 + rng.below(5), 1 + rng.below(5))];
        let n = 1 + rng.below(60);
        let shards = 1 + rng.below(8);
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: dims.clone(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: lorif::store::CodecId::Bf16,
        };
        let data = random_layers(n, &dims, 1, rng);
        let base = prop_tmp_base("partition", seed);
        let mut w = ShardedWriter::create(&base, meta, shards, n).unwrap();
        append_in_batches(&data, n, &mut Rng::labeled(seed, "batches"), |b| {
            w.append(b).unwrap()
        });
        let meta = w.finalize().unwrap();
        let counts = meta.shards.clone().unwrap();
        let per = (n + shards - 1) / shards;
        assert_eq!(
            counts.len(),
            ShardedWriter::expected_shards(n, shards),
            "seed {seed}: predicted shard count"
        );
        assert_eq!(counts.iter().sum::<usize>(), n, "seed {seed}");
        for (i, &cnt) in counts.iter().enumerate() {
            if i + 1 < counts.len() {
                assert_eq!(cnt, per, "seed {seed}: shard {i}");
            } else {
                assert!(cnt >= 1 && cnt <= per, "seed {seed}: last shard {cnt}");
            }
        }
        let set = ShardSet::open(&base).unwrap();
        let mut expect_start = 0usize;
        for i in 0..set.n_shards() {
            assert_eq!(set.shard(i).start, expect_start, "seed {seed}");
            expect_start += set.shard(i).count;
        }
        assert_eq!(expect_start, n, "seed {seed}");
    });
}

// ---------------------------------------------------------------------------
// score-sink invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_streaming_topk_equals_full_matrix_all_kernels() {
    // For every store scorer (graddot, logra, trackstar on dense
    // stores; lorif on factored stores), both store layouts (v1
    // monolithic, v2 sharded), and k in {1, 5, N}: the streaming
    // top-k sink returns exactly the indices of a stable descending
    // argsort of the full-matrix sink, while holding at most
    // Nq * k * shards score elements (never the (Nq, N) matrix).
    use lorif::attribution::graddot::GradDotScorer;
    use lorif::attribution::logra::LograScorer;
    use lorif::attribution::lorif::LorifScorer;
    use lorif::attribution::trackstar::TrackStarScorer;
    use lorif::attribution::{QueryGrads, QueryLayer, Scorer, SinkSpec};
    use lorif::curvature::{DenseCurvature, TruncatedCurvature};

    for_each_case("sink-equivalence", |seed, rng| {
        let n_layers = 1 + rng.below(2);
        let dims: Vec<(usize, usize)> =
            (0..n_layers).map(|_| (3 + rng.below(3), 3 + rng.below(3))).collect();
        let c = 1 + rng.below(2);
        let n = 12 + rng.below(25);
        let nq = 1 + rng.below(3);
        let shards = 2 + rng.below(3);
        let data = random_layers(n, &dims, c, rng);

        // the same records in every (kind, layout) combination
        let mut bases = std::collections::BTreeMap::new();
        for kind in [StoreKind::Dense, StoreKind::Factored] {
            let meta = StoreMeta {
                kind,
                tier: "small".into(),
                f: 4,
                c,
                layers: dims.clone(),
                n_examples: 0,
                shards: None,
                summary_chunk: None,
                codec: lorif::store::CodecId::Bf16,
            };
            let v1 = prop_tmp_base(&format!("sink_{}_v1", kind.as_str()), seed);
            let mut w = StoreWriter::create(&v1, meta.clone()).unwrap();
            append_in_batches(&data, n, &mut Rng::labeled(seed, "b1"), |b| {
                w.append(b).unwrap()
            });
            w.finalize().unwrap();
            let v2 = prop_tmp_base(&format!("sink_{}_v2", kind.as_str()), seed);
            let mut w = ShardedWriter::create(&v2, meta, shards, n).unwrap();
            append_in_batches(&data, n, &mut Rng::labeled(seed, "b2"), |b| {
                w.append(b).unwrap()
            });
            w.finalize().unwrap();
            bases.insert(kind.as_str(), (v1, v2));
        }
        let (dense_v1, dense_v2) = bases["dense"].clone();
        let (fact_v1, fact_v2) = bases["factored"].clone();

        let qlayers: Vec<QueryLayer> = dims
            .iter()
            .map(|&(d1, d2)| QueryLayer {
                g: Mat::random_normal(nq, d1 * d2, 1.0, rng),
                u: Mat::random_normal(nq, d1 * c, 1.0, rng),
                v: Mat::random_normal(nq, d2 * c, 1.0, rng),
            })
            .collect();
        let qg = QueryGrads { n_query: nq, c, proj_dims: dims.clone(), layers: qlayers };

        let chunk_size = 1 + rng.below(n);
        let threads = 1 + rng.below(3);
        let mut check = |name: &str, scorer: &mut dyn Scorer, n_shards: usize| {
            let full = scorer.score(&qg).unwrap();
            for k in [1usize, 5, n] {
                let streamed = scorer.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
                assert_eq!(
                    streamed.topk(k),
                    full.topk(k),
                    "seed {seed}: {name} k={k} diverged"
                );
                assert!(
                    streamed.peak_sink_elems <= nq * k * n_shards,
                    "seed {seed}: {name} k={k} held {} score elements (> {})",
                    streamed.peak_sink_elems,
                    nq * k * n_shards
                );
                // any pruned chunks are accounted byte-for-byte
                assert_eq!(
                    streamed.bytes_read + streamed.bytes_skipped,
                    full.bytes_read,
                    "seed {seed}: {name}"
                );
            }
        };

        for (layout, dense_base, fact_base) in
            [("v1", &dense_v1, &fact_v1), ("v2", &dense_v2, &fact_v2)]
        {
            let open_dense = || ShardSet::open(dense_base).unwrap();
            let open_fact = || ShardSet::open(fact_base).unwrap();
            let n_shards = open_dense().n_shards();

            let mut gd = GradDotScorer::new(open_dense());
            gd.chunk_size = chunk_size;
            gd.score_threads = threads;
            check(&format!("graddot/{layout}"), &mut gd, n_shards);

            let curv = DenseCurvature::build(&open_dense(), 0.1).unwrap();
            let mut lg = LograScorer::new(open_dense(), curv);
            lg.chunk_size = chunk_size;
            lg.score_threads = threads;
            check(&format!("logra/{layout}"), &mut lg, n_shards);

            let curv = DenseCurvature::build(&open_dense(), 0.1).unwrap();
            let mut ts = TrackStarScorer::new(open_dense(), curv);
            ts.chunk_size = chunk_size;
            ts.score_threads = threads;
            check(&format!("trackstar/{layout}"), &mut ts, n_shards);

            let curv = TruncatedCurvature::build(&open_fact(), 3, 3, 2, 0.1, seed).unwrap();
            let mut lf = LorifScorer::new(open_fact(), curv);
            lf.chunk_size = chunk_size;
            lf.score_threads = threads;
            check(&format!("lorif/{layout}"), &mut lf, n_shards);
        }
    });
}

#[test]
fn prop_topk_nan_injection_consistent() {
    // regression for the partial_cmp().unwrap() panic: scores with
    // injected NaNs must not panic, and the heap path (parallel::topk /
    // the streaming sink) must agree with the argsort path exactly
    use lorif::attribution::ScoreReport;
    use lorif::util::timer::PhaseTimer;
    for_each_case("nan-topk", |seed, rng| {
        let nq = 1 + rng.below(3);
        let n = 5 + rng.below(60);
        let mut scores = Mat::random_normal(nq, n, 1.0, rng);
        for _ in 0..(1 + rng.below(5)) {
            let q = rng.below(nq);
            let t = rng.below(n);
            *scores.at_mut(q, t) = if rng.below(2) == 0 { f32::NAN } else { -f32::NAN };
        }
        let k = 1 + rng.below(n);
        let threads = 1 + rng.below(4);
        let want = ScoreReport::full(scores.clone(), PhaseTimer::new(), 0).topk(k);
        let got = lorif::query::parallel::topk(&scores, k, threads);
        assert_eq!(got, want, "seed {seed} (n={n} k={k})");
    });
}

#[test]
fn prop_reconstruct_row_rank_additivity() {
    // reconstruct(u, v, c) == sum_k reconstruct(u_k, v_k, 1)
    use lorif::curvature::reconstruct_row;
    for_each_case("reconstruct", |seed, rng| {
        let d1 = 2 + rng.below(10);
        let d2 = 2 + rng.below(10);
        let c = 1 + rng.below(4);
        let u: Vec<f32> = (0..d1 * c).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..d2 * c).map(|_| rng.normal() as f32).collect();
        let mut full = vec![0.0f32; d1 * d2];
        reconstruct_row(&u, &v, d1, d2, c, &mut full);
        let mut acc = vec![0.0f32; d1 * d2];
        for k in 0..c {
            let uk: Vec<f32> = (0..d1).map(|a| u[a * c + k]).collect();
            let vk: Vec<f32> = (0..d2).map(|b| v[b * c + k]).collect();
            let mut one = vec![0.0f32; d1 * d2];
            reconstruct_row(&uk, &vk, d1, d2, 1, &mut one);
            for (a, o) in acc.iter_mut().zip(&one) {
                *a += o;
            }
        }
        for (x, y) in full.iter().zip(&acc) {
            assert!((x - y).abs() < 1e-4, "seed {seed}");
        }
    });
}

// ---------------------------------------------------------------------------
// chunk-pruning invariants (crate::sketch)
// ---------------------------------------------------------------------------

#[test]
fn prop_truncated_or_corrupted_sharded_store_fails_cleanly() {
    // random sharded stores: truncating any shard file, or corrupting
    // the summary sidecar, must surface as a clean error from
    // ShardSet::open — never a panic or a silent short read.
    for_each_case("shard-truncate", |seed, rng| {
        let dims = vec![(1 + rng.below(6), 1 + rng.below(6))];
        let n = 8 + rng.below(40);
        let shards = 2 + rng.below(4);
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: dims.clone(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: lorif::store::CodecId::Bf16,
        };
        let data = random_layers(n, &dims, 1, rng);
        let base = prop_tmp_base("truncate", seed);
        let mut w = ShardedWriter::create(&base, meta, shards, n).unwrap();
        append_in_batches(&data, n, &mut Rng::labeled(seed, "batches"), |b| {
            w.append(b).unwrap()
        });
        let meta = w.finalize().unwrap();
        assert!(ShardSet::open(&base).is_ok(), "seed {seed}: fresh store must open");

        // truncate a random shard by a random non-zero tail
        let victim = rng.below(meta.shards.as_ref().unwrap().len());
        let p = StoreMeta::shard_data_path(&base, victim);
        let bytes = std::fs::read(&p).unwrap();
        let cut = 1 + rng.below(bytes.len().min(64));
        std::fs::write(&p, &bytes[..bytes.len() - cut]).unwrap();
        let err = ShardSet::open(&base).unwrap_err();
        assert!(
            format!("{err}").contains("size mismatch"),
            "seed {seed}: unexpected error {err}"
        );
        std::fs::write(&p, &bytes).unwrap();
        assert!(ShardSet::open(&base).is_ok(), "seed {seed}: restored store must open");

        // corrupt the v3 summary sidecar: also a clean open-time error
        let sp = StoreMeta::summaries_path(&base);
        let sbytes = std::fs::read(&sp).unwrap();
        let cut = 1 + rng.below(sbytes.len());
        std::fs::write(&sp, &sbytes[..sbytes.len() - cut]).unwrap();
        assert!(ShardSet::open(&base).is_err(), "seed {seed}: corrupt sidecar accepted");
        std::fs::write(&sp, &sbytes).unwrap();
    });
}

#[test]
fn prop_exact_pruning_equals_full_scan_all_kernels() {
    // For every store kernel (graddot, logra, trackstar on dense
    // stores; lorif on factored stores), both layouts (v1 monolithic,
    // v2 sharded), clustered records, and a small summary grid: the
    // pruned streaming-top-k pass returns BIT-IDENTICAL top-k indices
    // to the full-scan argsort, and every skipped byte is accounted
    // (bytes_read + bytes_skipped == full-scan bytes).  Across the case
    // sweep, the clustered data must actually trigger skips.
    use lorif::attribution::graddot::GradDotScorer;
    use lorif::attribution::logra::LograScorer;
    use lorif::attribution::lorif::LorifScorer;
    use lorif::attribution::trackstar::TrackStarScorer;
    use lorif::attribution::{QueryGrads, QueryLayer, Scorer, SinkSpec};
    use lorif::curvature::{DenseCurvature, TruncatedCurvature};
    use lorif::sketch::PruneMode;

    let single_case =
        std::env::var("LORIF_PROP_SEED").map(|s| !s.trim().is_empty()).unwrap_or(false);
    let mut total_skipped = 0u64;
    for_each_case("prune-exact", |seed, rng| {
        let n_layers = 1 + rng.below(2);
        let dims: Vec<(usize, usize)> =
            (0..n_layers).map(|_| (3 + rng.below(3), 3 + rng.below(3))).collect();
        let c = 1 + rng.below(2);
        let grid = 3 + rng.below(5);
        let n = 4 * grid + rng.below(3 * grid);
        let nq = 1 + rng.below(3);
        let shards = 2 + rng.below(3);
        let k = 1 + rng.below(4);

        // clustered records: chunk 0 strong and query-aligned, later
        // chunks weak — the shape pruning exists for
        let data: Vec<LayerGrads> = dims
            .iter()
            .map(|&(d1, d2)| {
                let mut g = Mat::zeros(n, d1 * d2);
                let mut u = Mat::zeros(n, d1 * c);
                let mut v = Mat::zeros(n, d2 * c);
                for t in 0..n {
                    let scale = if t < grid { 4.0 } else { 0.02 };
                    for x in g.row_mut(t) {
                        *x = scale * (1.0 + 0.1 * rng.normal() as f32);
                    }
                    for x in u.row_mut(t) {
                        *x = scale * (1.0 + 0.1 * rng.normal() as f32);
                    }
                    for x in v.row_mut(t) {
                        *x = 1.0 + 0.1 * rng.normal() as f32;
                    }
                }
                LayerGrads { g, u, v }
            })
            .collect();

        let mut bases = std::collections::BTreeMap::new();
        for kind in [StoreKind::Dense, StoreKind::Factored] {
            let meta = StoreMeta {
                kind,
                tier: "small".into(),
                f: 4,
                c,
                layers: dims.clone(),
                n_examples: 0,
                shards: None,
                summary_chunk: None,
                codec: lorif::store::CodecId::Bf16,
            };
            let v1 = prop_tmp_base(&format!("prune_{}_v1", kind.as_str()), seed);
            let mut w = StoreWriter::create(&v1, meta.clone()).unwrap();
            w.set_summary_chunk(grid).unwrap();
            append_in_batches(&data, n, &mut Rng::labeled(seed, "b1"), |b| {
                w.append(b).unwrap()
            });
            let m = w.finalize().unwrap();
            assert_eq!(m.summary_chunk, Some(grid), "seed {seed}");
            let v2 = prop_tmp_base(&format!("prune_{}_v2", kind.as_str()), seed);
            let mut w = ShardedWriter::create(&v2, meta, shards, n).unwrap();
            w.set_summary_chunk(grid).unwrap();
            append_in_batches(&data, n, &mut Rng::labeled(seed, "b2"), |b| {
                w.append(b).unwrap()
            });
            w.finalize().unwrap();
            bases.insert(kind.as_str(), (v1, v2));
        }
        let (dense_v1, dense_v2) = bases["dense"].clone();
        let (fact_v1, fact_v2) = bases["factored"].clone();

        // queries aligned with the strong cluster's direction
        let qlayers: Vec<QueryLayer> = dims
            .iter()
            .map(|&(d1, d2)| {
                let mut g = Mat::zeros(nq, d1 * d2);
                let mut u = Mat::zeros(nq, d1 * c);
                let mut v = Mat::zeros(nq, d2 * c);
                for q in 0..nq {
                    for x in g.row_mut(q) {
                        *x = 1.0 + 0.1 * rng.normal() as f32;
                    }
                    for x in u.row_mut(q) {
                        *x = 1.0 + 0.1 * rng.normal() as f32;
                    }
                    for x in v.row_mut(q) {
                        *x = 1.0 + 0.1 * rng.normal() as f32;
                    }
                }
                QueryLayer { g, u, v }
            })
            .collect();
        let qg = QueryGrads { n_query: nq, c, proj_dims: dims.clone(), layers: qlayers };

        let threads = 1 + rng.below(3);
        let mut check = |name: &str, scorer: &mut dyn Scorer| {
            // reference: full-matrix pass (never pruned) + stable argsort
            let full = scorer.score(&qg).unwrap();
            // pruned: the scorers default to PruneMode::Exact
            let pruned = scorer.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
            assert_eq!(
                pruned.topk(k),
                full.topk(k),
                "seed {seed}: {name} pruned top-k diverged from the full scan"
            );
            assert_eq!(
                pruned.bytes_read + pruned.bytes_skipped,
                full.bytes_read,
                "seed {seed}: {name} byte accounting broken"
            );
            total_skipped += pruned.bytes_skipped;
        };

        for (layout, dense_base, fact_base) in
            [("v1", &dense_v1, &fact_v1), ("v2", &dense_v2, &fact_v2)]
        {
            let open_dense = || ShardSet::open(dense_base).unwrap();
            let open_fact = || ShardSet::open(fact_base).unwrap();

            let mut gd = GradDotScorer::new(open_dense());
            gd.score_threads = threads;
            check(&format!("graddot/{layout}"), &mut gd);

            let curv = DenseCurvature::build(&open_dense(), 0.1).unwrap();
            let mut lg = LograScorer::new(open_dense(), curv);
            lg.score_threads = threads;
            check(&format!("logra/{layout}"), &mut lg);

            let curv = DenseCurvature::build(&open_dense(), 0.1).unwrap();
            let mut ts = TrackStarScorer::new(open_dense(), curv);
            ts.score_threads = threads;
            check(&format!("trackstar/{layout}"), &mut ts);

            let curv = TruncatedCurvature::build(&open_fact(), 3, 3, 2, 0.1, seed).unwrap();
            let mut lf = LorifScorer::new(open_fact(), curv);
            lf.score_threads = threads;
            check(&format!("lorif/{layout}"), &mut lf);
        }

        // slack mode: still a valid top-k (right arity), skips at least
        // as many bytes as exact mode on the same store
        let mut gd = GradDotScorer::new(ShardSet::open(&dense_v1).unwrap());
        let exact = gd.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
        gd.prune = PruneMode::Slack(0.5);
        let slack = gd.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
        assert!(
            slack.bytes_skipped >= exact.bytes_skipped,
            "seed {seed}: slack pruned less than exact"
        );
        assert_eq!(slack.topk(k).len(), nq, "seed {seed}");
        // prune off: reads everything
        gd.prune = PruneMode::Off;
        let off = gd.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
        assert_eq!(off.bytes_skipped, 0, "seed {seed}");
    });
    if !single_case {
        assert!(
            total_skipped > 0,
            "clustered stores across the whole sweep never skipped a byte"
        );
    }
}

#[test]
fn prop_cached_scoring_bit_identical_all_kernels() {
    // For every store kernel (graddot, logra, trackstar on dense
    // stores; lorif on factored stores) and both layouts (v1
    // monolithic, v2 sharded — both carrying the default v3 summary
    // sidecar): scoring through a decoded-chunk cache is BIT-IDENTICAL
    // to cold scoring, on the full-matrix pass (cold, populate, and
    // cache-hit passes compared element-for-element) and on the pruned
    // streaming top-k pass.  Prune skips never populate the cache
    // (insertions == the pass's misses), warm passes hit, and a
    // tiny-budget cache (evictions / oversized chunks) changes nothing
    // but the counters.
    use lorif::attribution::graddot::GradDotScorer;
    use lorif::attribution::logra::LograScorer;
    use lorif::attribution::lorif::LorifScorer;
    use lorif::attribution::trackstar::TrackStarScorer;
    use lorif::attribution::{QueryGrads, QueryLayer, Scorer, SinkSpec};
    use lorif::curvature::{DenseCurvature, TruncatedCurvature};
    use lorif::sketch::PruneMode;
    use lorif::store::ChunkCache;
    use std::sync::Arc;

    for_each_case("cache-bit-identical", |seed, rng| {
        let n_layers = 1 + rng.below(2);
        let dims: Vec<(usize, usize)> =
            (0..n_layers).map(|_| (3 + rng.below(3), 3 + rng.below(3))).collect();
        let c = 1 + rng.below(2);
        let n = 12 + rng.below(25);
        let nq = 1 + rng.below(3);
        let shards = 2 + rng.below(3);
        let k = 1 + rng.below(6);
        let data = random_layers(n, &dims, c, rng);

        // identical records in every (kind, layout) combination
        let mut bases = std::collections::BTreeMap::new();
        for kind in [StoreKind::Dense, StoreKind::Factored] {
            let meta = StoreMeta {
                kind,
                tier: "small".into(),
                f: 4,
                c,
                layers: dims.clone(),
                n_examples: 0,
                shards: None,
                summary_chunk: None,
                codec: lorif::store::CodecId::Bf16,
            };
            let v1 = prop_tmp_base(&format!("cache_{}_v1", kind.as_str()), seed);
            let mut w = StoreWriter::create(&v1, meta.clone()).unwrap();
            append_in_batches(&data, n, &mut Rng::labeled(seed, "cb1"), |b| {
                w.append(b).unwrap()
            });
            w.finalize().unwrap();
            let v2 = prop_tmp_base(&format!("cache_{}_v2", kind.as_str()), seed);
            let mut w = ShardedWriter::create(&v2, meta, shards, n).unwrap();
            append_in_batches(&data, n, &mut Rng::labeled(seed, "cb2"), |b| {
                w.append(b).unwrap()
            });
            w.finalize().unwrap();
            bases.insert(kind.as_str(), (v1, v2));
        }
        let (dense_v1, dense_v2) = bases["dense"].clone();
        let (fact_v1, fact_v2) = bases["factored"].clone();

        let qlayers: Vec<QueryLayer> = dims
            .iter()
            .map(|&(d1, d2)| QueryLayer {
                g: Mat::random_normal(nq, d1 * d2, 1.0, rng),
                u: Mat::random_normal(nq, d1 * c, 1.0, rng),
                v: Mat::random_normal(nq, d2 * c, 1.0, rng),
            })
            .collect();
        let qg = QueryGrads { n_query: nq, c, proj_dims: dims.clone(), layers: qlayers };

        let chunk_size = 1 + rng.below(n);
        // three cache budgets: generous (everything resident), tiny
        // (evictions or oversized-skip), and none (the cold reference)
        let tiny_budget = 1 + rng.below(4096) as u64 * 64;

        let check = |name: &str,
                     cold: &mut dyn Scorer,
                     warm: &mut dyn Scorer,
                     tiny: &mut dyn Scorer,
                     cache: &Arc<ChunkCache>| {
            let reference = cold.score(&qg).unwrap();
            assert_eq!(
                reference.cache_hits + reference.cache_misses,
                0,
                "seed {seed}: {name} cold pass touched a cache"
            );
            // pass 1 populates, pass 2 hits; both bit-identical to cold
            for pass in 0..2 {
                let got = warm.score(&qg).unwrap();
                assert_eq!(
                    got.scores().data,
                    reference.scores().data,
                    "seed {seed}: {name} cached pass {pass} diverged"
                );
                assert_eq!(got.bytes_read, reference.bytes_read, "seed {seed}: {name}");
                if pass == 0 {
                    assert_eq!(got.cache_hits, 0, "seed {seed}: {name} fresh cache hit");
                    assert!(got.cache_misses > 0, "seed {seed}: {name} no misses counted");
                } else {
                    assert!(got.cache_hits > 0, "seed {seed}: {name} warm pass missed");
                    assert_eq!(got.cache_misses, 0, "seed {seed}: {name}");
                    assert_eq!(
                        got.bytes_from_cache, got.bytes_read,
                        "seed {seed}: {name} warm pass read disk"
                    );
                }
            }
            // tiny budget: correctness unaffected
            let got = tiny.score(&qg).unwrap();
            assert_eq!(
                got.scores().data,
                reference.scores().data,
                "seed {seed}: {name} tiny-budget cache diverged"
            );

            // pruned streaming top-k through the cache (fresh grid keys:
            // the summary grid differs from chunk_size in general).
            // First pruned pass: skips must NOT populate the cache —
            // insertions grow by exactly this pass's misses.
            let ins_before = cache.stats().insertions;
            let p1 = warm.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
            let ins_after = cache.stats().insertions;
            assert_eq!(
                p1.topk(k),
                reference.topk(k),
                "seed {seed}: {name} pruned+cached top-k diverged"
            );
            assert_eq!(
                p1.bytes_read + p1.bytes_skipped,
                reference.bytes_read,
                "seed {seed}: {name} byte accounting broke under the cache"
            );
            assert!(
                ins_after - ins_before <= p1.cache_misses as u64,
                "seed {seed}: {name} cache grew by {} for {} misses — a skipped \
                 chunk was inserted",
                ins_after - ins_before,
                p1.cache_misses
            );
            // second pruned pass: same skips, reads served hot
            let p2 = warm.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
            assert_eq!(p2.topk(k), p1.topk(k), "seed {seed}: {name}");
            assert_eq!(p2.chunks_skipped, p1.chunks_skipped, "seed {seed}: {name}");
            assert_eq!(
                p2.cache_hits, p1.cache_hits + p1.cache_misses,
                "seed {seed}: {name} second pruned pass not fully hot"
            );
        };

        for (layout, dense_base, fact_base) in
            [("v1", &dense_v1, &fact_v1), ("v2", &dense_v2, &fact_v2)]
        {
            let open_cold = |b: &std::path::PathBuf| ShardSet::open(b).unwrap();
            let open_cached = |b: &std::path::PathBuf, cap: u64| {
                let mut s = ShardSet::open(b).unwrap();
                let cache = ChunkCache::with_capacity(cap);
                s.set_cache(Some(cache.clone()));
                (s, cache)
            };

            {
                let (warm_set, cache) = open_cached(dense_base, 32 << 20);
                let (tiny_set, _) = open_cached(dense_base, tiny_budget);
                let mut cold = GradDotScorer::new(open_cold(dense_base));
                let mut warm = GradDotScorer::new(warm_set);
                let mut tiny = GradDotScorer::new(tiny_set);
                for s in [&mut cold, &mut warm, &mut tiny] {
                    s.chunk_size = chunk_size;
                    s.score_threads = 1;
                }
                check(&format!("graddot/{layout}"), &mut cold, &mut warm, &mut tiny, &cache);
            }
            {
                let curv = DenseCurvature::build(&open_cold(dense_base), 0.1).unwrap();
                let curv = Arc::new(curv);
                let (warm_set, cache) = open_cached(dense_base, 32 << 20);
                let (tiny_set, _) = open_cached(dense_base, tiny_budget);
                let mut cold = LograScorer::new(open_cold(dense_base), Arc::clone(&curv));
                let mut warm = LograScorer::new(warm_set, Arc::clone(&curv));
                let mut tiny = LograScorer::new(tiny_set, Arc::clone(&curv));
                for s in [&mut cold, &mut warm, &mut tiny] {
                    s.chunk_size = chunk_size;
                    s.score_threads = 1;
                }
                check(&format!("logra/{layout}"), &mut cold, &mut warm, &mut tiny, &cache);
            }
            {
                let curv = DenseCurvature::build(&open_cold(dense_base), 0.1).unwrap();
                let curv = Arc::new(curv);
                let (warm_set, cache) = open_cached(dense_base, 32 << 20);
                let (tiny_set, _) = open_cached(dense_base, tiny_budget);
                let mut cold = TrackStarScorer::new(open_cold(dense_base), Arc::clone(&curv));
                let mut warm = TrackStarScorer::new(warm_set, Arc::clone(&curv));
                let mut tiny = TrackStarScorer::new(tiny_set, Arc::clone(&curv));
                for s in [&mut cold, &mut warm, &mut tiny] {
                    s.chunk_size = chunk_size;
                    s.score_threads = 1;
                }
                check(&format!("trackstar/{layout}"), &mut cold, &mut warm, &mut tiny, &cache);
            }
            {
                let curv =
                    TruncatedCurvature::build(&open_cold(fact_base), 3, 3, 2, 0.1, seed)
                        .unwrap();
                let curv = Arc::new(curv);
                let (warm_set, cache) = open_cached(fact_base, 32 << 20);
                let (tiny_set, _) = open_cached(fact_base, tiny_budget);
                let mut cold = LorifScorer::new(open_cold(fact_base), Arc::clone(&curv));
                let mut warm = LorifScorer::new(warm_set, Arc::clone(&curv));
                let mut tiny = LorifScorer::new(tiny_set, Arc::clone(&curv));
                for s in [&mut cold, &mut warm, &mut tiny] {
                    s.chunk_size = chunk_size;
                    s.score_threads = 1;
                    s.prune = PruneMode::Exact;
                }
                check(&format!("lorif/{layout}"), &mut cold, &mut warm, &mut tiny, &cache);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// store-codec invariants (store::codec, store::recode)
// ---------------------------------------------------------------------------

#[test]
fn prop_codec_roundtrip_error_bounds() {
    // For every codec and random segments across magnitudes: the
    // encoded length matches `encoded_len`, and every decoded value is
    // within `max_rel_error() * (scale-group absmax)` of the original —
    // the exact contract the summary-sidecar inflation relies on.
    use lorif::store::{Codec, CodecId, INT4_GROUP};

    for_each_case("codec-bounds", |seed, rng| {
        for id in CodecId::ALL {
            let codec = id.get();
            let n = 1 + rng.below(200);
            let mag = 10f64.powi(rng.below(7) as i32 - 3);
            let src: Vec<f32> = (0..n).map(|_| (rng.normal() * mag) as f32).collect();
            let mut bytes = Vec::new();
            codec.encode(&src, &mut bytes);
            assert_eq!(bytes.len(), codec.encoded_len(n), "seed {seed}: {id:?} stride");
            let mut back = vec![0.0f32; n];
            codec.decode(&bytes, &mut back);
            // int4 scales per INT4_GROUP values; bf16/int8 per segment
            let group = if id == CodecId::Int4 { INT4_GROUP } else { n };
            for g in (0..n).step_by(group) {
                let hi = (g + group).min(n);
                let m = src[g..hi].iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                for i in g..hi {
                    assert!(
                        (src[i] - back[i]).abs() <= codec.max_rel_error() * m + 1e-30,
                        "seed {seed}: {id:?} n={n} i={i}: {} -> {} (group absmax {m})",
                        src[i],
                        back[i]
                    );
                }
            }
        }
    });
}

#[test]
fn prop_codec_recode_roundtrip_is_stable_and_preserves_structure() {
    // Random stores migrated bf16 -> int8 -> bf16 -> int8: every hop
    // preserves count/kind/shard layout/summary grid, the int8 decode
    // is within the codec bound of the source, and the second int8
    // store decodes within one bf16 rounding of the first (the
    // quantized integers are stable; only the f32 scale may wobble).
    use lorif::store::{recode_store, Codec, CodecId, RecodeOptions};

    fn collect(base: &std::path::Path) -> Vec<f32> {
        let set = ShardSet::open(base).unwrap();
        let mut out = Vec::new();
        set.stream(5, false, |chunk| {
            for layer in &chunk.layers {
                match layer {
                    lorif::store::ChunkLayer::Dense { g } => out.extend(g.data.iter()),
                    lorif::store::ChunkLayer::Factored { u, v } => {
                        out.extend(u.data.iter());
                        out.extend(v.data.iter());
                    }
                }
            }
            Ok(())
        })
        .unwrap();
        out
    }

    for_each_case("recode-roundtrip", |seed, rng| {
        let n_layers = 1 + rng.below(2);
        let dims: Vec<(usize, usize)> =
            (0..n_layers).map(|_| (1 + rng.below(7), 1 + rng.below(7))).collect();
        let c = 1 + rng.below(3.min(dims.iter().map(|&(a, b)| a.min(b)).min().unwrap()));
        let n = 6 + rng.below(30);
        let shards = 1 + rng.below(4);
        let grid = 3 + rng.below(4);
        let kind = if rng.below(2) == 0 { StoreKind::Dense } else { StoreKind::Factored };
        let meta = StoreMeta {
            kind,
            tier: "small".into(),
            f: 4,
            c,
            layers: dims.clone(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: CodecId::Bf16,
        };
        let data = random_layers(n, &dims, c, rng);
        let base = prop_tmp_base("recode_src", seed);
        if shards <= 1 {
            let mut w = StoreWriter::create(&base, meta).unwrap();
            w.set_summary_chunk(grid).unwrap();
            append_in_batches(&data, n, &mut Rng::labeled(seed, "rb"), |b| {
                w.append(b).unwrap()
            });
            w.finalize().unwrap();
        } else {
            let mut w = ShardedWriter::create(&base, meta, shards, n).unwrap();
            w.set_summary_chunk(grid).unwrap();
            append_in_batches(&data, n, &mut Rng::labeled(seed, "rb"), |b| {
                w.append(b).unwrap()
            });
            w.finalize().unwrap();
        }
        let src_meta = StoreMeta::load(&base).unwrap();
        let src_vals = collect(&base);

        // hop 1: bf16 -> int8, layout preserved
        let b8 = prop_tmp_base("recode_i8", seed);
        let rep = recode_store(
            &base,
            &b8,
            &RecodeOptions {
                codec: Some(CodecId::Int8),
                chunk_size: 1 + rng.below(9),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(rep.n_examples, n, "seed {seed}");
        assert_eq!(rep.kind, kind, "seed {seed}");
        assert_eq!(rep.version, 4, "seed {seed}");
        let m8 = StoreMeta::load(&b8).unwrap();
        assert_eq!(m8.shards, src_meta.shards, "seed {seed}: shard layout changed");
        assert_eq!(m8.summary_chunk, src_meta.summary_chunk, "seed {seed}: grid changed");
        assert_eq!(m8.codec, CodecId::Int8, "seed {seed}");
        assert!(rep.dst_bytes < rep.src_bytes, "seed {seed}: int8 did not shrink");
        let v8 = collect(&b8);
        assert_eq!(v8.len(), src_vals.len(), "seed {seed}");
        let absmax = src_vals.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let rel = CodecId::Int8.get().max_rel_error();
        for (a, b) in src_vals.iter().zip(&v8) {
            assert!(
                (a - b).abs() <= rel * absmax + 1e-30,
                "seed {seed}: int8 decode drifted: {a} vs {b}"
            );
        }

        // hop 2: int8 -> bf16 (back to a pre-v4 manifest)
        let bb = prop_tmp_base("recode_bf", seed);
        let rep = recode_store(
            &b8,
            &bb,
            &RecodeOptions { codec: Some(CodecId::Bf16), ..Default::default() },
        )
        .unwrap();
        assert!(rep.version <= 3, "seed {seed}: bf16 store must stay pre-v4");
        let vb = collect(&bb);
        for (a, b) in v8.iter().zip(&vb) {
            assert!(
                (a - b).abs() <= a.abs() / 256.0 + 1e-30,
                "seed {seed}: bf16 hop drifted: {a} vs {b}"
            );
        }

        // hop 3: bf16 -> int8 again; the quantized values are stable
        let b8b = prop_tmp_base("recode_i8b", seed);
        recode_store(
            &bb,
            &b8b,
            &RecodeOptions { codec: Some(CodecId::Int8), ..Default::default() },
        )
        .unwrap();
        let m8b = StoreMeta::load(&b8b).unwrap();
        assert_eq!(m8b.shards, src_meta.shards, "seed {seed}");
        let v8b = collect(&b8b);
        for (a, b) in v8.iter().zip(&v8b) {
            assert!(
                (a - b).abs() <= a.abs() / 128.0 + 1e-30,
                "seed {seed}: int8 -> bf16 -> int8 not stable: {a} vs {b}"
            );
        }
    });
}

#[test]
fn prop_codec_pruned_equals_full_and_cached_equals_cold() {
    // Per codec (bf16, int8, int4), per kernel (graddot on dense, lorif
    // on factored), over clustered stores with well-separated top
    // scores: (a) the pruned top-k pass EXACTLY matches that codec's
    // own full scan with every skipped byte accounted, (b) scoring
    // through a decoded-chunk cache is bit-identical to cold scoring,
    // and (c) graddot's top-k overlap vs the bf16 store is >= 0.95.
    // Across the sweep the clustered data must actually trigger skips.
    use lorif::attribution::graddot::GradDotScorer;
    use lorif::attribution::lorif::LorifScorer;
    use lorif::attribution::{QueryGrads, QueryLayer, Scorer, SinkSpec};
    use lorif::curvature::TruncatedCurvature;
    use lorif::sketch::PruneMode;
    use lorif::store::{recode_store, ChunkCache, CodecId, RecodeOptions};

    let single_case =
        std::env::var("LORIF_PROP_SEED").map(|s| !s.trim().is_empty()).unwrap_or(false);
    let mut total_skipped = 0u64;
    for_each_case("codec-scoring", |seed, rng| {
        // d1, d2 >= 3 keeps D >= 9 > r + oversample for the rSVD stage
        // (same floor the other scorer properties use)
        let dims: Vec<(usize, usize)> = vec![(3 + rng.below(3), 3 + rng.below(3))];
        let c = 1 + rng.below(2);
        let grid = 4;
        let n = grid * (4 + rng.below(3));
        let nq = 1 + rng.below(3);
        let shards = 1 + rng.below(3);
        let k = 1 + rng.below(3);

        // constant-valued rows with geometrically separated magnitudes
        // in the strong chunk: 25% gaps dwarf every codec's error, so
        // the true top-k is unambiguous under quantization
        let data: Vec<LayerGrads> = dims
            .iter()
            .map(|&(d1, d2)| {
                let mut g = Mat::zeros(n, d1 * d2);
                let mut u = Mat::zeros(n, d1 * c);
                let mut v = Mat::zeros(n, d2 * c);
                for t in 0..n {
                    let a = if t < grid { 3.0 * 0.75f32.powi(t as i32) } else { 0.01 };
                    g.row_mut(t).iter_mut().for_each(|x| *x = a);
                    u.row_mut(t).iter_mut().for_each(|x| *x = a);
                    // tiny jitter keeps the factored curvature full rank
                    // without threatening the 25% top-score separation
                    v.row_mut(t)
                        .iter_mut()
                        .for_each(|x| *x = 1.0 + 0.01 * rng.normal() as f32);
                }
                LayerGrads { g, u, v }
            })
            .collect();

        let mut bases = std::collections::BTreeMap::new();
        for kind in [StoreKind::Dense, StoreKind::Factored] {
            let meta = StoreMeta {
                kind,
                tier: "small".into(),
                f: 4,
                c,
                layers: dims.clone(),
                n_examples: 0,
                shards: None,
                summary_chunk: None,
                codec: CodecId::Bf16,
            };
            let base = prop_tmp_base(&format!("codecsc_{}", kind.as_str()), seed);
            if shards <= 1 {
                let mut w = StoreWriter::create(&base, meta).unwrap();
                w.set_summary_chunk(grid).unwrap();
                append_in_batches(&data, n, &mut Rng::labeled(seed, "cs"), |b| {
                    w.append(b).unwrap()
                });
                w.finalize().unwrap();
            } else {
                let mut w = ShardedWriter::create(&base, meta, shards, n).unwrap();
                w.set_summary_chunk(grid).unwrap();
                append_in_batches(&data, n, &mut Rng::labeled(seed, "cs"), |b| {
                    w.append(b).unwrap()
                });
                w.finalize().unwrap();
            }
            bases.insert(kind.as_str(), base);
        }

        let qlayers: Vec<QueryLayer> = dims
            .iter()
            .map(|&(d1, d2)| QueryLayer {
                g: Mat::from_vec(nq, d1 * d2, vec![1.0; nq * d1 * d2]),
                u: Mat::from_vec(nq, d1 * c, vec![1.0; nq * d1 * c]),
                v: Mat::from_vec(nq, d2 * c, vec![1.0; nq * d2 * c]),
            })
            .collect();
        let qg = QueryGrads { n_query: nq, c, proj_dims: dims.clone(), layers: qlayers };

        let mut bf16_topk: Option<Vec<Vec<usize>>> = None;
        for codec in CodecId::ALL {
            // per-codec store: the bf16 original, or a recode of it
            let store_for = |kind: &str| {
                let src = &bases[kind];
                if codec == CodecId::Bf16 {
                    src.clone()
                } else {
                    let dst = prop_tmp_base(
                        &format!("codecsc_{kind}_{}", codec.as_str()),
                        seed,
                    );
                    let opts =
                        RecodeOptions { codec: Some(codec), ..Default::default() };
                    recode_store(src, &dst, &opts).unwrap();
                    dst
                }
            };
            let dense_base = store_for("dense");
            let fact_base = store_for("factored");

            let mut check = |name: &str, scorer: &mut dyn Scorer| -> Vec<Vec<usize>> {
                // (a) this codec's own full scan is the exactness bar
                let full = scorer.score(&qg).unwrap();
                let pruned = scorer.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
                assert_eq!(
                    pruned.topk(k),
                    full.topk(k),
                    "seed {seed}: {name}/{codec:?} pruned top-k != full scan"
                );
                assert_eq!(
                    pruned.bytes_read + pruned.bytes_skipped,
                    full.bytes_read,
                    "seed {seed}: {name}/{codec:?} byte accounting broken"
                );
                total_skipped += pruned.bytes_skipped;
                full.topk(k)
            };

            let open = |b: &std::path::PathBuf| ShardSet::open(b).unwrap();
            let mut gd = GradDotScorer::new(open(&dense_base));
            gd.prune = PruneMode::Exact;
            let gd_topk = check("graddot", &mut gd);

            let curv = TruncatedCurvature::build(&open(&fact_base), 3, 3, 2, 0.1, seed).unwrap();
            let mut lf = LorifScorer::new(open(&fact_base), curv);
            lf.prune = PruneMode::Exact;
            check("lorif", &mut lf);

            // (b) cached scoring is bit-identical per codec
            let cold = GradDotScorer::new(open(&dense_base)).score(&qg).unwrap();
            let mut warm_set = open(&dense_base);
            warm_set.set_cache(Some(ChunkCache::with_capacity(32 << 20)));
            let mut warm = GradDotScorer::new(warm_set);
            for pass in 0..2 {
                let got = warm.score(&qg).unwrap();
                assert_eq!(
                    got.scores().data,
                    cold.scores().data,
                    "seed {seed}: {codec:?} cached pass {pass} diverged from cold"
                );
                if pass == 1 {
                    assert!(got.cache_hits > 0, "seed {seed}: {codec:?} warm pass missed");
                    assert_eq!(got.cache_misses, 0, "seed {seed}: {codec:?}");
                }
            }

            // (c) overlap@k against the bf16 reference
            match &bf16_topk {
                None => bf16_topk = Some(gd_topk),
                Some(reference) => {
                    let mut inter = 0usize;
                    let mut total = 0usize;
                    for (a, b) in reference.iter().zip(&gd_topk) {
                        total += a.len();
                        inter += a.iter().filter(|i| b.contains(i)).count();
                    }
                    let overlap = inter as f64 / total.max(1) as f64;
                    assert!(
                        overlap >= 0.95,
                        "seed {seed}: {codec:?} overlap@{k} = {overlap} < 0.95"
                    );
                }
            }
        }
    });
    if !single_case {
        assert!(
            total_skipped > 0,
            "clustered codec stores across the whole sweep never skipped a byte"
        );
    }
}

// ---------------------------------------------------------------------------
// clustered-store (v5) invariants (store::cluster, store::recode,
// the best-first executor)
// ---------------------------------------------------------------------------

#[test]
fn prop_clustered_exact_equals_unclustered_full_scan_all_kernels() {
    // For every store kernel (graddot, logra, trackstar on dense
    // stores; lorif on factored stores) and every record codec
    // (bf16/int8/int4): scoring a `--cluster`-reordered (v5) store in
    // exact best-first mode returns BIT-IDENTICAL top-k indices to the
    // unclustered store's full scan — the permutation maps every index
    // back to caller coordinates, the full-matrix pass post-permutes to
    // the same score matrix, and the best-first walk accounts every
    // skipped byte (bytes_read + bytes_skipped == full-scan bytes).
    use lorif::attribution::graddot::GradDotScorer;
    use lorif::attribution::logra::LograScorer;
    use lorif::attribution::lorif::LorifScorer;
    use lorif::attribution::trackstar::TrackStarScorer;
    use lorif::attribution::{QueryGrads, QueryLayer, Scorer, SinkSpec};
    use lorif::curvature::{DenseCurvature, TruncatedCurvature};
    use lorif::sketch::PruneMode;
    use lorif::store::{recode_store, ClusterMeta, CodecId, RecodeOptions};
    use std::sync::Arc;

    for_each_case("clustered-exact", |seed, rng| {
        let dims: Vec<(usize, usize)> = vec![(3 + rng.below(3), 3 + rng.below(3))];
        let c = 1 + rng.below(2);
        let grid = 4;
        let n = grid * (4 + rng.below(3));
        let nq = 1 + rng.below(3);
        let shards = 1 + rng.below(3);
        let k = 1 + rng.below(4);
        let kc = 2 + rng.below(3);
        let data = random_layers(n, &dims, c, rng);

        // unclustered bf16 sources (with the summary grid), per kind
        let mut bases = std::collections::BTreeMap::new();
        for kind in [StoreKind::Dense, StoreKind::Factored] {
            let meta = StoreMeta {
                kind,
                tier: "small".into(),
                f: 4,
                c,
                layers: dims.clone(),
                n_examples: 0,
                shards: None,
                summary_chunk: None,
                codec: CodecId::Bf16,
            };
            let base = prop_tmp_base(&format!("clx_{}", kind.as_str()), seed);
            if shards <= 1 {
                let mut w = StoreWriter::create(&base, meta).unwrap();
                w.set_summary_chunk(grid).unwrap();
                append_in_batches(&data, n, &mut Rng::labeled(seed, "cx"), |b| {
                    w.append(b).unwrap()
                });
                w.finalize().unwrap();
            } else {
                let mut w = ShardedWriter::create(&base, meta, shards, n).unwrap();
                w.set_summary_chunk(grid).unwrap();
                append_in_batches(&data, n, &mut Rng::labeled(seed, "cx"), |b| {
                    w.append(b).unwrap()
                });
                w.finalize().unwrap();
            }
            bases.insert(kind.as_str(), base);
        }

        let qlayers: Vec<QueryLayer> = dims
            .iter()
            .map(|&(d1, d2)| QueryLayer {
                g: Mat::random_normal(nq, d1 * d2, 1.0, rng),
                u: Mat::random_normal(nq, d1 * c, 1.0, rng),
                v: Mat::random_normal(nq, d2 * c, 1.0, rng),
            })
            .collect();
        let qg = QueryGrads { n_query: nq, c, proj_dims: dims.clone(), layers: qlayers };

        for codec in CodecId::ALL {
            // per codec: the flat (unclustered) store and its clustered
            // twin — same records, same codec, reordered + permuted
            let store_pair = |kind: &str| {
                let src = &bases[kind];
                let flat = if codec == CodecId::Bf16 {
                    src.clone()
                } else {
                    let dst =
                        prop_tmp_base(&format!("clx_{kind}_{}", codec.as_str()), seed);
                    recode_store(
                        src,
                        &dst,
                        &RecodeOptions { codec: Some(codec), ..Default::default() },
                    )
                    .unwrap();
                    dst
                };
                let clustered =
                    prop_tmp_base(&format!("clx_{kind}_{}_v5", codec.as_str()), seed);
                let rep = recode_store(
                    src,
                    &clustered,
                    &RecodeOptions {
                        codec: Some(codec),
                        cluster: Some(kc),
                        ..Default::default()
                    },
                )
                .unwrap();
                assert_eq!(rep.cluster, Some(kc), "seed {seed}: cluster not attached");
                assert_eq!(rep.version, 5, "seed {seed}");
                (flat, clustered)
            };
            let (dense_flat, dense_cl) = store_pair("dense");
            let (fact_flat, fact_cl) = store_pair("factored");
            let open = |b: &std::path::Path| ShardSet::open(b).unwrap();

            let cm = ClusterMeta::load(&dense_cl).unwrap().expect("v5 store lost its perm");
            cm.validate(n).unwrap();

            let check = |name: &str, flat: &mut dyn Scorer, cl: &mut dyn Scorer| {
                let full = flat.score(&qg).unwrap();
                let full_cl = cl.score(&qg).unwrap();
                assert_eq!(
                    full_cl.scores().data,
                    full.scores().data,
                    "seed {seed}: {name}/{codec:?} clustered full matrix not \
                     permuted back to caller coordinates"
                );
                let pruned = cl.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
                assert_eq!(
                    pruned.topk(k),
                    full.topk(k),
                    "seed {seed}: {name}/{codec:?} clustered exact top-k diverged \
                     from the unclustered full scan"
                );
                assert_eq!(
                    pruned.bytes_read + pruned.bytes_skipped,
                    full.bytes_read,
                    "seed {seed}: {name}/{codec:?} best-first byte ledger broken"
                );
            };

            {
                let mut a = GradDotScorer::new(open(&dense_flat));
                a.prune = PruneMode::Off;
                let mut b = GradDotScorer::new(open(&dense_cl));
                b.prune = PruneMode::Exact;
                check("graddot", &mut a, &mut b);
            }
            {
                let curv =
                    Arc::new(DenseCurvature::build(&open(&dense_flat), 0.1).unwrap());
                let mut a = LograScorer::new(open(&dense_flat), Arc::clone(&curv));
                a.prune = PruneMode::Off;
                let mut b = LograScorer::new(open(&dense_cl), Arc::clone(&curv));
                b.prune = PruneMode::Exact;
                check("logra", &mut a, &mut b);
            }
            {
                let curv =
                    Arc::new(DenseCurvature::build(&open(&dense_flat), 0.1).unwrap());
                let mut a = TrackStarScorer::new(open(&dense_flat), Arc::clone(&curv));
                a.prune = PruneMode::Off;
                let mut b = TrackStarScorer::new(open(&dense_cl), Arc::clone(&curv));
                b.prune = PruneMode::Exact;
                check("trackstar", &mut a, &mut b);
            }
            {
                let curv = Arc::new(
                    TruncatedCurvature::build(&open(&fact_flat), 3, 3, 2, 0.1, seed)
                        .unwrap(),
                );
                let mut a = LorifScorer::new(open(&fact_flat), Arc::clone(&curv));
                a.prune = PruneMode::Off;
                let mut b = LorifScorer::new(open(&fact_cl), Arc::clone(&curv));
                b.prune = PruneMode::Exact;
                check("lorif", &mut a, &mut b);
            }
        }
    });
}

#[test]
fn prop_cluster_permutation_roundtrips() {
    // `--cluster` recodes record a bijective permutation whose inverse
    // composes to the identity, place each original record at the
    // storage position the permutation claims, and carry the
    // permutation unchanged through later plain recodes.
    use lorif::store::{recode_store, ClusterMeta, CodecId, RecodeOptions};

    for_each_case("cluster-perm", |seed, rng| {
        let dims = vec![(1 + rng.below(6), 1 + rng.below(6))];
        let n = 8 + rng.below(40);
        let kc = 1 + rng.below(6.min(n));
        let grid = 2 + rng.below(5);
        let data = random_layers(n, &dims, 1, rng);
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: dims.clone(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: CodecId::Bf16,
        };
        let base = prop_tmp_base("clperm_src", seed);
        let mut w = StoreWriter::create(&base, meta).unwrap();
        w.set_summary_chunk(grid).unwrap();
        append_in_batches(&data, n, &mut Rng::labeled(seed, "cp"), |b| {
            w.append(b).unwrap()
        });
        w.finalize().unwrap();

        let dst = prop_tmp_base("clperm_v5", seed);
        let rep = recode_store(
            &base,
            &dst,
            &RecodeOptions { cluster: Some(kc), ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.cluster, Some(kc), "seed {seed}");
        assert_eq!(rep.version, 5, "seed {seed}");

        let cm = ClusterMeta::load(&dst).unwrap().expect("v5 store without a perm");
        cm.validate(n).unwrap();
        let inv = cm.inverse();
        for orig in 0..n {
            assert_eq!(
                cm.original(inv[orig] as usize),
                orig,
                "seed {seed}: inverse does not round-trip"
            );
        }

        // storage position p holds the record the caller knows as perm[p]
        let src = ShardSet::open(&base).unwrap();
        let cl = ShardSet::open(&dst).unwrap();
        for _ in 0..5 {
            let p = rng.below(n);
            let a = cl.read_range(p, 1).unwrap();
            let b = src.read_range(cm.original(p), 1).unwrap();
            assert_eq!(
                a.layers[0].dense().data,
                b.layers[0].dense().data,
                "seed {seed}: storage {p} does not hold original {}",
                cm.original(p)
            );
        }

        // a plain codec recode of the v5 store carries the perm through
        let dst2 = prop_tmp_base("clperm_carry", seed);
        recode_store(
            &dst,
            &dst2,
            &RecodeOptions { codec: Some(CodecId::Int8), ..Default::default() },
        )
        .unwrap();
        assert_eq!(
            ClusterMeta::load(&dst2).unwrap(),
            Some(cm),
            "seed {seed}: permutation lost in a plain recode"
        );
    });
}

#[test]
fn prop_recall_mode_certified_overlap_meets_target() {
    // `--prune recall=x` stops early only once ceil(x*k) heap entries
    // per query are certified (strictly above every unvisited chunk's
    // bound), so per-query overlap@k against the full scan is >= x by
    // construction — and recall=1.0 is bit-identical to the full scan.
    // The early stop still accounts every unread byte.
    use lorif::attribution::graddot::GradDotScorer;
    use lorif::attribution::{QueryGrads, QueryLayer, Scorer, SinkSpec};
    use lorif::sketch::PruneMode;
    use lorif::store::{recode_store, CodecId, RecodeOptions};

    for_each_case("recall-overlap", |seed, rng| {
        let dims: Vec<(usize, usize)> = vec![(3 + rng.below(3), 3 + rng.below(3))];
        let grid = 4;
        let n = grid * (4 + rng.below(4));
        let nq = 1 + rng.below(3);
        let shards = 1 + rng.below(3);
        let k = 1 + rng.below(4);
        let kc = 2 + rng.below(3);

        // strong query-aligned head rows so the certified stop can
        // actually trigger before the scan ends
        let data: Vec<LayerGrads> = dims
            .iter()
            .map(|&(d1, d2)| {
                let mut g = Mat::zeros(n, d1 * d2);
                for t in 0..n {
                    let scale = if t < grid { 4.0 } else { 0.02 };
                    for x in g.row_mut(t) {
                        *x = scale * (1.0 + 0.1 * rng.normal() as f32);
                    }
                }
                LayerGrads { g, u: Mat::zeros(n, d1), v: Mat::zeros(n, d2) }
            })
            .collect();
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: dims.clone(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: CodecId::Bf16,
        };
        let base = prop_tmp_base("recall_src", seed);
        if shards <= 1 {
            let mut w = StoreWriter::create(&base, meta).unwrap();
            w.set_summary_chunk(grid).unwrap();
            append_in_batches(&data, n, &mut Rng::labeled(seed, "rc"), |b| {
                w.append(b).unwrap()
            });
            w.finalize().unwrap();
        } else {
            let mut w = ShardedWriter::create(&base, meta, shards, n).unwrap();
            w.set_summary_chunk(grid).unwrap();
            append_in_batches(&data, n, &mut Rng::labeled(seed, "rc"), |b| {
                w.append(b).unwrap()
            });
            w.finalize().unwrap();
        }
        let dst = prop_tmp_base("recall_v5", seed);
        recode_store(&base, &dst, &RecodeOptions { cluster: Some(kc), ..Default::default() })
            .unwrap();

        let qlayers: Vec<QueryLayer> = dims
            .iter()
            .map(|&(d1, d2)| QueryLayer {
                g: Mat::from_vec(nq, d1 * d2, vec![1.0; nq * d1 * d2]),
                u: Mat::zeros(nq, d1),
                v: Mat::zeros(nq, d2),
            })
            .collect();
        let qg = QueryGrads { n_query: nq, c: 1, proj_dims: dims.clone(), layers: qlayers };

        let mut flat = GradDotScorer::new(ShardSet::open(&base).unwrap());
        flat.prune = PruneMode::Off;
        let full = flat.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
        let reference = full.topk(k);

        for x in [0.5f32, 0.9, 1.0] {
            let mut s = GradDotScorer::new(ShardSet::open(&dst).unwrap());
            s.prune = PruneMode::Recall(x);
            let r = s.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
            assert_eq!(
                r.bytes_read + r.bytes_skipped,
                full.bytes_read,
                "seed {seed}: recall={x} byte ledger broken"
            );
            let got = r.topk(k);
            let need = (x * k as f32).ceil().max(1.0) as usize;
            for (q, (want, have)) in reference.iter().zip(&got).enumerate() {
                let inter = want.iter().filter(|i| have.contains(i)).count();
                assert!(
                    inter >= need.min(want.len()),
                    "seed {seed}: recall={x} query {q} kept {inter} of {} certified \
                     entries (need {need})",
                    want.len()
                );
            }
            if (x - 1.0).abs() < 1e-9 {
                assert_eq!(
                    got, reference,
                    "seed {seed}: recall=1.0 must equal the full scan exactly"
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// quantized-domain scoring invariants (store::codec::quant)
// ---------------------------------------------------------------------------

#[test]
fn prop_codec_quant_scoring_equals_decode_then_score() {
    // For every codec and every store kernel (graddot/logra/trackstar
    // on dense stores, lorif on factored): scoring with --quant-score
    // on (integer dot products over the encoded bytes, scales folded
    // in) matches decode-then-score.  bf16 and the lorif kernel are
    // BIT-IDENTICAL (the fused path runs the same f32 kernels in the
    // same per-element order); int8/int4 agree within the codec's
    // documented max_rel_error bound — the real divergence is only f32
    // rounding order, so the codec bound is a comfortably safe ceiling.
    // Under quant-on the pruned streaming top-k still equals its own
    // full scan with every skipped byte accounted, and scoring through
    // the (encoded-resident) chunk cache is bit-identical to a cold
    // quant pass, with the second pass served fully hot.
    use lorif::attribution::graddot::GradDotScorer;
    use lorif::attribution::logra::LograScorer;
    use lorif::attribution::lorif::LorifScorer;
    use lorif::attribution::trackstar::TrackStarScorer;
    use lorif::attribution::{QueryGrads, QueryLayer, Scorer, SinkSpec};
    use lorif::curvature::{DenseCurvature, TruncatedCurvature};
    use lorif::store::{
        recode_store, ChunkCache, Codec, CodecId, QuantScore, RecodeOptions,
    };
    use std::sync::Arc;

    for_each_case("codec-quant", |seed, rng| {
        let dims: Vec<(usize, usize)> = vec![(3 + rng.below(3), 3 + rng.below(3))];
        let c = 1 + rng.below(2);
        let grid = 4;
        let n = grid * (4 + rng.below(3));
        let nq = 1 + rng.below(3);
        let shards = 1 + rng.below(3);
        let k = 1 + rng.below(3);

        // clustered magnitudes (strong chunk 0, geometric 25% gaps) so
        // exact pruning has something to skip; 5% jitter varies the
        // quantized codes without threatening the top-score separation
        let data: Vec<LayerGrads> = dims
            .iter()
            .map(|&(d1, d2)| {
                let mut g = Mat::zeros(n, d1 * d2);
                let mut u = Mat::zeros(n, d1 * c);
                let mut v = Mat::zeros(n, d2 * c);
                for t in 0..n {
                    let a = if t < grid { 3.0 * 0.75f32.powi(t as i32) } else { 0.01 };
                    g.row_mut(t)
                        .iter_mut()
                        .for_each(|x| *x = a * (1.0 + 0.05 * rng.normal() as f32));
                    u.row_mut(t)
                        .iter_mut()
                        .for_each(|x| *x = a * (1.0 + 0.05 * rng.normal() as f32));
                    v.row_mut(t)
                        .iter_mut()
                        .for_each(|x| *x = 1.0 + 0.01 * rng.normal() as f32);
                }
                LayerGrads { g, u, v }
            })
            .collect();

        let mut bases = std::collections::BTreeMap::new();
        for kind in [StoreKind::Dense, StoreKind::Factored] {
            let meta = StoreMeta {
                kind,
                tier: "small".into(),
                f: 4,
                c,
                layers: dims.clone(),
                n_examples: 0,
                shards: None,
                summary_chunk: None,
                codec: CodecId::Bf16,
            };
            let base = prop_tmp_base(&format!("quantsc_{}", kind.as_str()), seed);
            if shards <= 1 {
                let mut w = StoreWriter::create(&base, meta).unwrap();
                w.set_summary_chunk(grid).unwrap();
                append_in_batches(&data, n, &mut Rng::labeled(seed, "qs"), |b| {
                    w.append(b).unwrap()
                });
                w.finalize().unwrap();
            } else {
                let mut w = ShardedWriter::create(&base, meta, shards, n).unwrap();
                w.set_summary_chunk(grid).unwrap();
                append_in_batches(&data, n, &mut Rng::labeled(seed, "qs"), |b| {
                    w.append(b).unwrap()
                });
                w.finalize().unwrap();
            }
            bases.insert(kind.as_str(), base);
        }

        let qlayers: Vec<QueryLayer> = dims
            .iter()
            .map(|&(d1, d2)| QueryLayer {
                g: Mat::from_vec(nq, d1 * d2, vec![1.0; nq * d1 * d2]),
                u: Mat::from_vec(nq, d1 * c, vec![1.0; nq * d1 * c]),
                v: Mat::from_vec(nq, d2 * c, vec![1.0; nq * d2 * c]),
            })
            .collect();
        let qg = QueryGrads { n_query: nq, c, proj_dims: dims.clone(), layers: qlayers };

        for codec in CodecId::ALL {
            let store_for = |kind: &str| {
                let src = &bases[kind];
                if codec == CodecId::Bf16 {
                    src.clone()
                } else {
                    let dst = prop_tmp_base(
                        &format!("quantsc_{kind}_{}", codec.as_str()),
                        seed,
                    );
                    let opts =
                        RecodeOptions { codec: Some(codec), ..Default::default() };
                    recode_store(src, &dst, &opts).unwrap();
                    dst
                }
            };
            let dense_base = store_for("dense");
            let fact_base = store_for("factored");
            let open = |b: &std::path::PathBuf| ShardSet::open(b).unwrap();

            // bit_exact: the quant path provably reruns the identical f32
            // kernels (bf16 segments decode to scratch; lorif decodes the
            // whole chunk in-kernel for every codec)
            let mut check = |name: &str,
                             off: &mut dyn Scorer,
                             on: &mut dyn Scorer,
                             bit_exact: bool| {
                let reference = off.score(&qg).unwrap();
                let quant = on.score(&qg).unwrap();
                assert_eq!(
                    quant.bytes_read, reference.bytes_read,
                    "seed {seed}: {name}/{codec:?} logical bytes changed under quant"
                );
                if bit_exact {
                    assert_eq!(
                        quant.scores().data,
                        reference.scores().data,
                        "seed {seed}: {name}/{codec:?} quant path not bit-identical"
                    );
                } else {
                    let scale = reference
                        .scores()
                        .data
                        .iter()
                        .fold(0.0f32, |m, &x| m.max(x.abs()));
                    let tol = codec.get().max_rel_error() * scale.max(1.0) + 1e-6;
                    for (a, b) in
                        reference.scores().data.iter().zip(&quant.scores().data)
                    {
                        assert!(
                            (a - b).abs() <= tol,
                            "seed {seed}: {name}/{codec:?} quant {b} vs decoded {a} \
                             (tol {tol})"
                        );
                    }
                }
                // pruned + quant-on: exact vs its own full scan, every
                // skipped byte accounted
                let pruned = on.score_sink(&qg, SinkSpec::TopK(k)).unwrap();
                assert_eq!(
                    pruned.topk(k),
                    quant.topk(k),
                    "seed {seed}: {name}/{codec:?} pruned+quant top-k diverged"
                );
                assert_eq!(
                    pruned.bytes_read + pruned.bytes_skipped,
                    quant.bytes_read,
                    "seed {seed}: {name}/{codec:?} byte accounting broken under quant"
                );
            };
            let exact = codec == CodecId::Bf16;

            {
                let mut off = GradDotScorer::new(open(&dense_base));
                off.quant = QuantScore::Off;
                let mut on = GradDotScorer::new(open(&dense_base));
                on.quant = QuantScore::On;
                check("graddot", &mut off, &mut on, exact);
            }
            {
                let curv =
                    Arc::new(DenseCurvature::build(&open(&dense_base), 0.1).unwrap());
                let mut off = LograScorer::new(open(&dense_base), Arc::clone(&curv));
                off.quant = QuantScore::Off;
                let mut on = LograScorer::new(open(&dense_base), Arc::clone(&curv));
                on.quant = QuantScore::On;
                check("logra", &mut off, &mut on, exact);
            }
            {
                let curv =
                    Arc::new(DenseCurvature::build(&open(&dense_base), 0.1).unwrap());
                let mut off =
                    TrackStarScorer::new(open(&dense_base), Arc::clone(&curv));
                off.quant = QuantScore::Off;
                let mut on = TrackStarScorer::new(open(&dense_base), Arc::clone(&curv));
                on.quant = QuantScore::On;
                check("trackstar", &mut off, &mut on, exact);
            }
            {
                let curv = Arc::new(
                    TruncatedCurvature::build(&open(&fact_base), 3, 3, 2, 0.1, seed)
                        .unwrap(),
                );
                let mut off = LorifScorer::new(open(&fact_base), Arc::clone(&curv));
                off.quant = QuantScore::Off;
                let mut on = LorifScorer::new(open(&fact_base), Arc::clone(&curv));
                on.quant = QuantScore::On;
                // lorif decodes in-kernel: bit-identical for EVERY codec
                check("lorif", &mut off, &mut on, true);
            }

            // cached quant scoring: the cache now holds ENCODED bytes
            // (2-4x residency); both passes bit-identical to the cold
            // quant pass, second pass served fully hot
            let cold = {
                let mut s = GradDotScorer::new(open(&dense_base));
                s.quant = QuantScore::On;
                s.score(&qg).unwrap()
            };
            let mut warm_set = open(&dense_base);
            warm_set.set_cache(Some(ChunkCache::with_capacity(32 << 20)));
            let mut warm = GradDotScorer::new(warm_set);
            warm.quant = QuantScore::On;
            for pass in 0..2 {
                let got = warm.score(&qg).unwrap();
                assert_eq!(
                    got.scores().data,
                    cold.scores().data,
                    "seed {seed}: {codec:?} cached quant pass {pass} diverged"
                );
                assert_eq!(got.bytes_read, cold.bytes_read, "seed {seed}: {codec:?}");
                if pass == 1 {
                    assert!(
                        got.cache_hits > 0,
                        "seed {seed}: {codec:?} warm quant pass missed"
                    );
                    assert_eq!(got.cache_misses, 0, "seed {seed}: {codec:?}");
                }
            }
        }
    });
}

#[test]
fn prop_registry_counters_match_the_score_report_ledger_bit_for_bit() {
    // The telemetry registry is DERIVED from the per-pass ledgers: a
    // scoring pass run under `telemetry::with_registry` must publish
    // byte/cache counters into the scoped registry that equal the
    // pass's own ScoreReport fields exactly — across full, pruned,
    // cached, and quantized passes, at any thread count (the worker
    // pool re-installs the scope inside each shard job).  The ledger
    // invariant survives the indirection: registry bytes_read +
    // bytes_skipped of a pruned pass == the full pass's bytes_read.
    use lorif::attribution::graddot::GradDotScorer;
    use lorif::attribution::{QueryGrads, QueryLayer, Scorer, SinkSpec};
    use lorif::sketch::PruneMode;
    use lorif::store::{
        recode_store, ChunkCache, CodecId, QuantScore, RecodeOptions,
    };
    use lorif::telemetry::{with_registry, Registry};
    use std::sync::Arc;

    for_each_case("registry-ledger", |seed, rng| {
        let n_layers = 1 + rng.below(2);
        let dims: Vec<(usize, usize)> =
            (0..n_layers).map(|_| (3 + rng.below(3), 3 + rng.below(3))).collect();
        let grid = 3 + rng.below(5);
        let n = 4 * grid + rng.below(3 * grid);
        let nq = 1 + rng.below(3);
        let shards = 2 + rng.below(3);
        let k = 1 + rng.below(4);
        let threads = 1 + rng.below(3);

        // clustered records (strong first chunk) so pruning really skips
        let data: Vec<LayerGrads> = dims
            .iter()
            .map(|&(d1, d2)| {
                let mut g = Mat::zeros(n, d1 * d2);
                for t in 0..n {
                    let scale = if t < grid { 4.0 } else { 0.02 };
                    for x in g.row_mut(t) {
                        *x = scale * (1.0 + 0.1 * rng.normal() as f32);
                    }
                }
                LayerGrads { g, u: Mat::zeros(n, d1), v: Mat::zeros(n, d2) }
            })
            .collect();
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: dims.clone(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: CodecId::Bf16,
        };
        let base = prop_tmp_base("registry_ledger", seed);
        let mut w = ShardedWriter::create(&base, meta, shards, n).unwrap();
        w.set_summary_chunk(grid).unwrap();
        append_in_batches(&data, n, &mut Rng::labeled(seed, "rb"), |b| w.append(b).unwrap());
        w.finalize().unwrap();

        let qlayers: Vec<QueryLayer> = dims
            .iter()
            .map(|&(d1, d2)| {
                let mut g = Mat::zeros(nq, d1 * d2);
                for x in g.data.iter_mut() {
                    *x = 1.0 + 0.1 * rng.normal() as f32;
                }
                QueryLayer { g, u: Mat::zeros(nq, d1), v: Mat::zeros(nq, d2) }
            })
            .collect();
        let qg = QueryGrads { n_query: nq, c: 1, proj_dims: dims.clone(), layers: qlayers };

        // run one pass against a FRESH registry; check every counter the
        // report also carries for exact equality
        let scoped = |scorer: &mut dyn Scorer, sink: Option<usize>| {
            let reg = Arc::new(Registry::new());
            let report = with_registry(Arc::clone(&reg), || match sink {
                Some(k) => scorer.score_sink(&qg, SinkSpec::TopK(k)),
                None => scorer.score(&qg),
            })
            .unwrap();
            assert_eq!(reg.exec_passes.get(), 1, "seed {seed}: one pass, one publication");
            assert_eq!(reg.store_bytes_read.get(), report.bytes_read, "seed {seed}");
            assert_eq!(reg.store_bytes_skipped.get(), report.bytes_skipped, "seed {seed}");
            assert_eq!(
                reg.store_bytes_from_cache.get(),
                report.bytes_from_cache,
                "seed {seed}"
            );
            assert_eq!(reg.cache_hits.get(), report.cache_hits as u64, "seed {seed}");
            assert_eq!(reg.cache_misses.get(), report.cache_misses as u64, "seed {seed}");
            assert_eq!(
                reg.prune_bytes_skipped.get(),
                report.bytes_skipped,
                "seed {seed}: prune family mirrors the skip ledger"
            );
            (report, reg)
        };

        let open = || ShardSet::open(&base).unwrap();

        // full pass: everything read, nothing skipped
        let mut gd = GradDotScorer::new(open());
        gd.score_threads = threads;
        let (full, full_reg) = scoped(&mut gd, None);
        assert_eq!(full.bytes_skipped, 0, "seed {seed}: full pass skips nothing");

        // pruned top-k pass: the registry preserves the byte ledger
        let mut gd = GradDotScorer::new(open());
        gd.score_threads = threads;
        gd.prune = PruneMode::Exact;
        let (_, pruned_reg) = scoped(&mut gd, Some(k));
        assert_eq!(
            pruned_reg.store_bytes_read.get() + pruned_reg.store_bytes_skipped.get(),
            full_reg.store_bytes_read.get(),
            "seed {seed}: bytes_read + bytes_skipped must equal the full-scan bytes \
             when read entirely through the registry"
        );

        // cached passes: hits/insertions surface in the scoped registry
        let mut warm_set = open();
        warm_set.set_cache(Some(ChunkCache::with_capacity(32 << 20)));
        let mut warm = GradDotScorer::new(warm_set);
        warm.score_threads = threads;
        let (cold, cold_reg) = scoped(&mut warm, None);
        assert_eq!(cold.cache_hits, 0, "seed {seed}: first pass is cold");
        assert!(cold_reg.cache_insertions.get() > 0, "seed {seed}: cold pass fills the cache");
        let (hot, hot_reg) = scoped(&mut warm, None);
        assert!(hot.cache_hits > 0, "seed {seed}: second pass hits");
        assert_eq!(hot_reg.cache_misses.get(), 0, "seed {seed}");
        assert_eq!(
            hot_reg.store_bytes_from_cache.get(),
            hot_reg.store_bytes_read.get(),
            "seed {seed}: a fully warm pass reads only from the cache"
        );

        // quantized-domain pass on an int8 recode of the same store
        let q8 = prop_tmp_base("registry_ledger_int8", seed);
        recode_store(
            &base,
            &q8,
            &RecodeOptions { codec: Some(CodecId::Int8), ..Default::default() },
        )
        .unwrap();
        let mut qs = GradDotScorer::new(ShardSet::open(&q8).unwrap());
        qs.score_threads = threads;
        qs.quant = QuantScore::On;
        let (quant, _) = scoped(&mut qs, Some(k));
        assert!(quant.bytes_read > 0, "seed {seed}: quant pass streamed the store");
    });
}

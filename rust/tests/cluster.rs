//! Distributed-serving integration tests: an in-process cluster of
//! shard nodes behind a scatter-gather coordinator.
//!
//! The invariant every scenario asserts is the tentpole claim of the
//! distributed mode: **distributed ≡ local, bit for bit**.  Each node
//! is the ordinary attribution server over a SUBSET-opened store
//! (`ShardSet::open_subset` keeps global example coordinates); the
//! coordinator forwards raw token rows, gathers the per-node heaps via
//! the lossless `topk_bits` channel, and merges them with the same
//! `merge_topk` reduction the local executor uses.  We compare the
//! coordinator's wire replies against a direct local `score_sink` pass
//! over the full store — same kernel, same curvature, same deterministic
//! gradient extraction — as raw `(index, f32-bit-pattern)` pairs, for
//! all four store kernels and both exact prune modes.
//!
//! The failover scenario kills one node's primary mid-run and asserts
//! the replica answers its shards with the SAME exact results, and that
//! the retry is visible in `lorif_coord_retry/failover_total`.
//!
//! The fleet scenarios attach a `Fleet` monitor to the coordinator:
//! health probes must mark a black-holed (accepts, never replies)
//! primary `down` and route its scatter legs PROACTIVELY to the
//! replica — far under the io-timeout the reactive path would pay —
//! with the decisions visible in the reply's `NodeStat`s, the JSONL
//! event log, and the slow-query log; and the federation scrape loop
//! must merge every node's exposition into one labeled page whose
//! summed per-node byte ledger equals the local full-scan ledger.
//!
//! `LORIF_CLUSTER_NODES` raises the node count (the CI nightly
//! hardening job runs a wider cluster than the per-PR default of 3).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lorif::attribution::{QueryGrads, QueryLayer, ScoreOutput, Scorer, SinkSpec};
use lorif::curvature::{DenseCurvature, TruncatedCurvature};
use lorif::linalg::Mat;
use lorif::query::server::{GradSource, ServeSummary, Server, ServerConfig};
use lorif::query::{Fleet, FleetOptions, RemotePlane, ShardPlane, TokenSource, Topology};
use lorif::telemetry::federation;
use lorif::runtime::{ExtractBatch, LayerGrads};
use lorif::sketch::PruneMode;
use lorif::store::{CodecId, ShardSet, ShardedWriter, StoreKind, StoreMeta};
use lorif::util::json::Value;
use lorif::util::prng::Rng;

const VOCAB: usize = 64;
const SEQ_LEN: usize = 8;
const DIMS: [(usize, usize); 2] = [(4, 6), (3, 5)];
const C: usize = 2;
const N_QUERIES: usize = 5;
const K: usize = 7;

fn cluster_nodes() -> usize {
    std::env::var("LORIF_CLUSTER_NODES")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 2)
        .unwrap_or(3)
}

/// Deterministic CPU gradient source — a pure function of the token
/// row, so every node and the local reference extract IDENTICAL query
/// gradients (the property the exactness argument leans on).
struct FakeSource;

impl GradSource for FakeSource {
    fn vocab(&self) -> usize {
        VOCAB
    }

    fn seq_len(&self) -> usize {
        SEQ_LEN
    }

    fn extract(&mut self, tokens: &[i32], n: usize) -> anyhow::Result<QueryGrads> {
        assert_eq!(tokens.len(), n * SEQ_LEN, "batcher must hand fixed-length rows");
        let layers = DIMS
            .iter()
            .enumerate()
            .map(|(l, &(d1, d2))| {
                let mut g = Mat::zeros(n, d1 * d2);
                let mut u = Mat::zeros(n, d1 * C);
                let mut v = Mat::zeros(n, d2 * C);
                for q in 0..n {
                    let row = &tokens[q * SEQ_LEN..(q + 1) * SEQ_LEN];
                    for (j, x) in g.row_mut(q).iter_mut().enumerate() {
                        *x = (row[j % SEQ_LEN] as f32 - 31.5) * 0.0625
                            + (l + 1) as f32 * 0.125 * ((j % 5) as f32 - 2.0);
                    }
                    for (j, x) in u.row_mut(q).iter_mut().enumerate() {
                        *x = row[(j + 1) % SEQ_LEN] as f32 * 0.03125 - 0.75;
                    }
                    for (j, x) in v.row_mut(q).iter_mut().enumerate() {
                        *x = row[(j + 2) % SEQ_LEN] as f32 * 0.015625 + 0.25;
                    }
                }
                QueryLayer { g, u, v }
            })
            .collect();
        Ok(QueryGrads { n_query: n, c: C, proj_dims: DIMS.to_vec(), layers })
    }
}

fn query_tokens(q: usize) -> Vec<i32> {
    (0..SEQ_LEN).map(|j| ((q * 13 + j * 5 + 3) % VOCAB) as i32).collect()
}

fn tokens_line(tokens: &[i32]) -> String {
    let list: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    format!("{{\"tokens\": [{}]}}", list.join(", "))
}

/// The on-disk fixtures every setup shares: one dense + one factored
/// sharded store, and ONE curvature per family built from the FULL
/// store — exactly as production stage 2 does, so nodes and the local
/// reference precondition identically.
struct Stores {
    dense: PathBuf,
    factored: PathBuf,
    curv_dense: Arc<DenseCurvature>,
    curv_trunc: Arc<TruncatedCurvature>,
}

fn build_stores(name: &str, shards: usize, n: usize) -> Stores {
    let dir = std::env::temp_dir().join("lorif_cluster_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(271);
    let mut write = |kind: StoreKind, tag: &str| -> PathBuf {
        let base = dir.join(format!("{name}_{tag}"));
        let meta = StoreMeta {
            kind,
            tier: "small".into(),
            f: 4,
            c: C,
            layers: DIMS.to_vec(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: CodecId::Bf16,
        };
        let layers: Vec<LayerGrads> = DIMS
            .iter()
            .map(|&(d1, d2)| LayerGrads {
                g: Mat::random_normal(n, d1 * d2, 1.0, &mut rng),
                u: Mat::random_normal(n, d1 * C, 1.0, &mut rng),
                v: Mat::random_normal(n, d2 * C, 1.0, &mut rng),
            })
            .collect();
        let mut w = ShardedWriter::create(&base, meta, shards, n).unwrap();
        w.append(&ExtractBatch { losses: vec![0.0; n], layers, valid: n }).unwrap();
        w.finalize().unwrap();
        base
    };
    let dense = write(StoreKind::Dense, "dense");
    let factored = write(StoreKind::Factored, "factored");
    let curv_dense = Arc::new(DenseCurvature::build(&ShardSet::open(&dense).unwrap(), 0.1).unwrap());
    let curv_trunc =
        Arc::new(TruncatedCurvature::build(&ShardSet::open(&factored).unwrap(), 6, 8, 3, 0.1, 0).unwrap());
    Stores { dense, factored, curv_dense, curv_trunc }
}

#[derive(Clone, Copy, Debug)]
enum Kernel {
    GradDot,
    Logra,
    TrackStar,
    Lorif,
}

const KERNELS: [Kernel; 4] = [Kernel::GradDot, Kernel::Logra, Kernel::TrackStar, Kernel::Lorif];

/// One scorer over `subset` of the store's manifest shards (`None` =
/// the full store: the local reference).  Small chunks so the tiny
/// fixtures still exercise chunk streaming and the pruner.
fn make_scorer(
    kernel: Kernel,
    stores: &Stores,
    subset: Option<&[usize]>,
    prune: PruneMode,
) -> Box<dyn Scorer + Send> {
    match kernel {
        Kernel::GradDot => {
            let mut s = lorif::attribution::graddot::GradDotScorer::new(
                ShardSet::open_subset(&stores.dense, subset).unwrap(),
            );
            s.chunk_size = 5;
            s.score_threads = 1;
            s.prune = prune;
            Box::new(s)
        }
        Kernel::Logra => {
            let mut s = lorif::attribution::logra::LograScorer::new(
                ShardSet::open_subset(&stores.dense, subset).unwrap(),
                Arc::clone(&stores.curv_dense),
            );
            s.chunk_size = 5;
            s.score_threads = 1;
            s.prune = prune;
            Box::new(s)
        }
        Kernel::TrackStar => {
            let mut s = lorif::attribution::trackstar::TrackStarScorer::new(
                ShardSet::open_subset(&stores.dense, subset).unwrap(),
                Arc::clone(&stores.curv_dense),
            );
            s.chunk_size = 5;
            s.score_threads = 1;
            s.prune = prune;
            Box::new(s)
        }
        Kernel::Lorif => {
            let mut s = lorif::attribution::LorifScorer::new(
                ShardSet::open_subset(&stores.factored, subset).unwrap(),
                Arc::clone(&stores.curv_trunc),
            );
            s.chunk_size = 5;
            s.score_threads = 1;
            s.prune = prune;
            Box::new(s)
        }
    }
}

struct Running {
    addr: SocketAddr,
    handle: std::thread::JoinHandle<anyhow::Result<ServeSummary>>,
}

fn start_node(
    kernel: Kernel,
    stores: &Stores,
    subset: Vec<usize>,
    prune: PruneMode,
) -> Running {
    let scorers = vec![make_scorer(kernel, stores, Some(&subset), prune)];
    let server = Server::bind(ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 1,
        window_ms: 0,
        topk: K,
        queue_cap: 32,
        io_timeout_ms: 0,
        shards_served: subset.len(),
        slowlog_cap: 0,
    })
    .unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run(FakeSource, scorers));
    Running { addr, handle }
}

fn start_coordinator(spec: &str, io_timeout_ms: u64) -> Running {
    let topology = Topology::parse(spec, None).unwrap();
    let planes: Vec<Box<dyn ShardPlane + Send>> = vec![Box::new(RemotePlane {
        topology,
        io_timeout: (io_timeout_ms > 0).then(|| Duration::from_millis(io_timeout_ms)),
        fleet: None,
    })];
    let server = Server::bind(ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 1,
        window_ms: 0,
        topk: K,
        queue_cap: 32,
        io_timeout_ms,
        shards_served: 0,
        slowlog_cap: 8,
    })
    .unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || {
        server.run_planes(TokenSource { vocab: VOCAB, seq_len: SEQ_LEN }, planes)
    });
    Running { addr, handle }
}

/// A coordinator with a [`Fleet`] monitor attached: probe/scrape loops,
/// proactive routing, federated `metrics`, the `fleet` stats section,
/// and (optionally) the JSONL event log.
fn start_fleet_coordinator(
    spec: &str,
    io_timeout_ms: u64,
    opts: FleetOptions,
) -> (Running, Arc<Fleet>) {
    let topology = Topology::parse(spec, None).unwrap();
    let fleet = Fleet::new(topology.clone(), opts).unwrap();
    let planes: Vec<Box<dyn ShardPlane + Send>> = vec![Box::new(RemotePlane {
        topology,
        io_timeout: (io_timeout_ms > 0).then(|| Duration::from_millis(io_timeout_ms)),
        fleet: Some(Arc::clone(&fleet)),
    })];
    let mut server = Server::bind(ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 1,
        window_ms: 0,
        topk: K,
        queue_cap: 32,
        io_timeout_ms,
        shards_served: 0,
        slowlog_cap: 8,
    })
    .unwrap();
    server.set_fleet(Arc::clone(&fleet));
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || {
        server.run_planes(TokenSource { vocab: VOCAB, seq_len: SEQ_LEN }, planes)
    });
    (Running { addr, handle }, fleet)
}

/// A TCP endpoint that accepts connections and then NEVER replies — the
/// hung-node case, where only a read timeout (not a connect error)
/// reveals death.  Returns the address and a handle whose drop stops
/// the listener.
fn black_hole() -> (SocketAddr, std::sync::mpsc::Sender<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    std::thread::spawn(move || {
        listener.set_nonblocking(true).unwrap();
        let mut held: Vec<TcpStream> = Vec::new();
        loop {
            match rx.try_recv() {
                Err(std::sync::mpsc::TryRecvError::Empty) => {}
                _ => return,
            }
            match listener.accept() {
                Ok((s, _)) => held.push(s),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => return,
            }
        }
    });
    (addr, tx)
}

/// One request, one reply line, parsed.
fn request(addr: SocketAddr, line: &str) -> Value {
    let mut s = TcpStream::connect(addr).expect("connect");
    writeln!(s, "{line}").unwrap();
    let mut r = BufReader::new(s);
    let mut resp = String::new();
    r.read_line(&mut resp).expect("read reply");
    assert!(!resp.trim().is_empty(), "server must always reply (got EOF)");
    Value::parse(resp.trim()).expect("reply is JSON")
}

fn shutdown(r: Running) -> ServeSummary {
    let v = request(r.addr, "{\"cmd\": \"shutdown\"}");
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true));
    r.handle.join().expect("server thread").expect("serve result")
}

/// The local reference for one query: top-k as exact `(index, bits)`
/// pairs, plus the pass's total byte ledger (`read + skipped`, which is
/// scan-order-invariant even when pruning decisions differ).
fn local_reference(
    local: &mut Box<dyn Scorer + Send>,
    tokens: &[i32],
) -> (Vec<(usize, u32)>, u64) {
    let qg = FakeSource.extract(tokens, 1).unwrap();
    let rep = local.score_sink(&qg, SinkSpec::TopK(K)).unwrap();
    let total = rep.bytes_read + rep.bytes_skipped;
    let ScoreOutput::TopK(heaps) = &rep.output else {
        panic!("topk sink must produce heaps")
    };
    let bits = heaps[0].entries().iter().map(|&(s, i)| (i, s.to_bits())).collect();
    (bits, total)
}

/// The coordinator reply's top-k as exact `(index, bits)` pairs.
fn wire_bits(v: &Value) -> Vec<(usize, u32)> {
    v.get("topk_bits")
        .and_then(Value::as_arr)
        .unwrap_or_else(|| panic!("reply missing topk_bits: {v}"))
        .iter()
        .map(|pair| {
            let p = pair.as_arr().expect("pair");
            (p[0].as_usize().unwrap(), p[1].as_f64().unwrap() as u32)
        })
        .collect()
}

/// One sample value from a Prometheus text exposition.
fn metric_value(text: &str, name: &str) -> u64 {
    let prefix = format!("{name} ");
    let line = text
        .lines()
        .find(|l| l.starts_with(&prefix))
        .unwrap_or_else(|| panic!("exposition missing sample for {name}"));
    line[prefix.len()..].trim().parse::<f64>().expect("numeric sample") as u64
}

#[test]
fn distributed_equals_local_bit_for_bit_across_kernels_and_prune_modes() {
    let n_nodes = cluster_nodes();
    let shards = 2 * n_nodes;
    let stores = build_stores("exact", shards, shards * 8);

    for kernel in KERNELS {
        for prune in [PruneMode::Off, PruneMode::Exact] {
            // one node per contiguous shard pair
            let nodes: Vec<Running> = (0..n_nodes)
                .map(|i| start_node(kernel, &stores, vec![2 * i, 2 * i + 1], prune))
                .collect();
            let spec = nodes
                .iter()
                .enumerate()
                .map(|(i, n)| format!("{}={}-{}", n.addr, 2 * i, 2 * i + 1))
                .collect::<Vec<_>>()
                .join(",");
            let coord = start_coordinator(&spec, 0);

            let mut local = make_scorer(kernel, &stores, None, prune);
            for q in 0..N_QUERIES {
                let tokens = query_tokens(q);
                let (want, local_scan) = local_reference(&mut local, &tokens);
                let v = request(coord.addr, &tokens_line(&tokens));
                let got = wire_bits(&v);
                assert_eq!(
                    got, want,
                    "{kernel:?} prune {prune:?} query {q}: distributed != local"
                );

                // the reply's per-node stats cover the whole cluster,
                // nobody failed over
                let stats = v.get("nodes").and_then(Value::as_arr).unwrap_or_else(|| {
                    panic!("coordinator reply missing nodes array: {v}")
                });
                assert_eq!(stats.len(), n_nodes);
                assert!(stats
                    .iter()
                    .all(|s| s.get("failover").and_then(Value::as_bool) == Some(false)));

                // byte-ledger reconciliation: summed over nodes,
                // read + skipped still equals the local full-scan count
                // (what WAS read may differ under pruning — per-node
                // thresholds evolve independently — but the total is
                // invariant)
                let dist_scan = (v.get("bytes_read").and_then(Value::as_usize).unwrap()
                    + v.get("bytes_skipped").and_then(Value::as_usize).unwrap())
                    as u64;
                assert_eq!(
                    dist_scan, local_scan,
                    "{kernel:?} prune {prune:?} query {q}: byte ledgers do not reconcile"
                );
            }

            let summary = shutdown(coord);
            assert_eq!(summary.served, N_QUERIES, "{kernel:?} {prune:?}");
            assert_eq!(summary.failed, 0);
            for n in nodes {
                let s = shutdown(n);
                assert_eq!(s.served, N_QUERIES, "every node scored every query");
            }
        }
    }
}

#[test]
fn killing_a_node_mid_run_fails_over_to_its_replica_with_exact_results() {
    let n_nodes = cluster_nodes();
    let shards = 2 * n_nodes;
    let stores = build_stores("failover", shards, shards * 8);
    let (kernel, prune) = (Kernel::GradDot, PruneMode::Exact);

    let primaries: Vec<Running> = (0..n_nodes)
        .map(|i| start_node(kernel, &stores, vec![2 * i, 2 * i + 1], prune))
        .collect();
    // node 0's replica serves the SAME shard subset
    let replica = start_node(kernel, &stores, vec![0, 1], prune);
    let spec = primaries
        .iter()
        .enumerate()
        .map(|(i, n)| {
            if i == 0 {
                format!("{}=0-1/{}", n.addr, replica.addr)
            } else {
                format!("{}={}-{}", n.addr, 2 * i, 2 * i + 1)
            }
        })
        .collect::<Vec<_>>()
        .join(",");
    let coord = start_coordinator(&spec, 2000);

    let mut local = make_scorer(kernel, &stores, None, prune);
    // healthy phase: primaries answer, no failover
    for q in 0..2 {
        let tokens = query_tokens(q);
        let (want, _) = local_reference(&mut local, &tokens);
        let v = request(coord.addr, &tokens_line(&tokens));
        assert_eq!(wire_bits(&v), want, "healthy query {q}");
    }

    // kill node 0's primary MID-RUN (join so its port is fully released
    // before the next scatter tries it)
    let mut primaries = primaries.into_iter();
    let primary0 = primaries.next().unwrap();
    shutdown(primary0);

    // degraded phase: results must be COMPLETE and exact — shard 0-1
    // answered by the replica
    for q in 2..N_QUERIES {
        let tokens = query_tokens(q);
        let (want, _) = local_reference(&mut local, &tokens);
        let v = request(coord.addr, &tokens_line(&tokens));
        assert_eq!(wire_bits(&v), want, "failover query {q}: result incomplete or inexact");
        let stats = v.get("nodes").and_then(Value::as_arr).unwrap();
        let fo: Vec<&Value> = stats
            .iter()
            .filter(|s| s.get("failover").and_then(Value::as_bool) == Some(true))
            .collect();
        assert_eq!(fo.len(), 1, "exactly node 0 fails over: {v}");
        assert_eq!(
            fo[0].get("addr").and_then(Value::as_str),
            Some(replica.addr.to_string().as_str()),
            "the replica answered"
        );
        assert_eq!(fo[0].get("retries").and_then(Value::as_usize), Some(1));
    }

    // the retry is visible in the coordinator's own registry
    let m = request(coord.addr, "{\"cmd\": \"metrics\"}");
    let text = m.get("metrics").and_then(Value::as_str).unwrap().to_string();
    let failovers = metric_value(&text, "lorif_coord_failover_total");
    assert!(failovers >= 1, "failover not counted: {failovers}");
    assert!(metric_value(&text, "lorif_coord_retry_total") >= failovers);
    assert!(metric_value(&text, "lorif_coord_gather_total") >= 1);

    let summary = shutdown(coord);
    assert_eq!(summary.served, N_QUERIES, "every query answered despite the kill");
    assert_eq!(summary.failed, 0);
    for n in primaries {
        shutdown(n);
    }
    let s = shutdown(replica);
    assert_eq!(s.served, N_QUERIES - 2, "replica served exactly the post-kill queries");
}

/// A hung primary (accepts, never replies) is detected by the health
/// probes and routed around PROACTIVELY: scatter legs go straight to
/// the replica, so every query answers far under the `--io-timeout-ms`
/// the reactive retry path would have paid.  The decision is visible in
/// the reply's `NodeStat`s (`proactive`, zero retries), the `stats`
/// verb's fleet section, the federated metrics, the slow-query log, and
/// the JSONL event log.
#[test]
fn probe_marked_down_primary_is_routed_around_before_io_timeout() {
    let n_nodes = cluster_nodes();
    let shards = 2 * n_nodes;
    let stores = build_stores("probe", shards, shards * 8);
    let (kernel, prune) = (Kernel::GradDot, PruneMode::Off);

    // node 0's primary is a black hole; its REPLICA is the real server
    let (bh_addr, bh_stop) = black_hole();
    let replica = start_node(kernel, &stores, vec![0, 1], prune);
    let others: Vec<Running> =
        (1..n_nodes).map(|i| start_node(kernel, &stores, vec![2 * i, 2 * i + 1], prune)).collect();
    let mut parts = vec![format!("{bh_addr}=0-1/{}", replica.addr)];
    parts.extend(
        others.iter().enumerate().map(|(j, n)| format!("{}={}-{}", n.addr, 2 * (j + 1), 2 * (j + 1) + 1)),
    );
    let spec = parts.join(",");

    let dir = std::env::temp_dir().join(format!("lorif_cluster_events_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let events = dir.join("probe_failover.jsonl");
    let io_timeout_ms: u64 = 4000; // the bound the proactive route must beat
    let (coord, _fleet) = start_fleet_coordinator(
        &spec,
        io_timeout_ms,
        FleetOptions {
            probe_interval: Duration::from_millis(50),
            probe_timeout: Duration::from_millis(200),
            scrape_interval: Duration::from_millis(200),
            fail_threshold: 2,
            event_log: Some(events.clone()),
        },
    );

    // the probe loop alone (NO query traffic) must flip the black hole
    // to `down` within fail_threshold probe rounds plus slack
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let v = request(coord.addr, "{\"cmd\": \"stats\"}");
        let fleet_arr = v
            .get("fleet")
            .and_then(Value::as_arr)
            .expect("coordinator stats must carry a fleet section");
        assert_eq!(fleet_arr.len(), n_nodes + 1, "one endpoint per primary + replica");
        let state = fleet_arr
            .iter()
            .find(|e| e.get("addr").and_then(Value::as_str) == Some(bh_addr.to_string().as_str()))
            .and_then(|e| e.get("state").and_then(Value::as_str))
            .expect("black-hole endpoint listed")
            .to_string();
        if state == "down" {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "probes never marked the hung primary down (state {state})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // every query: exact results, answered by the replica with zero
    // retries, and far under the io-timeout (the reactive path would
    // block the full 4s on the black hole first)
    let mut local = make_scorer(kernel, &stores, None, prune);
    for q in 0..N_QUERIES {
        let tokens = query_tokens(q);
        let (want, _) = local_reference(&mut local, &tokens);
        let t0 = Instant::now();
        let v = request(coord.addr, &tokens_line(&tokens));
        let elapsed = t0.elapsed();
        assert_eq!(wire_bits(&v), want, "proactive query {q}: result incomplete or inexact");
        assert!(
            elapsed < Duration::from_millis(io_timeout_ms / 2),
            "query {q} took {elapsed:?}: the scatter paid the io-timeout it must avoid"
        );
        let stats = v.get("nodes").and_then(Value::as_arr).unwrap();
        let fo: Vec<&Value> = stats
            .iter()
            .filter(|s| s.get("failover").and_then(Value::as_bool) == Some(true))
            .collect();
        assert_eq!(fo.len(), 1, "exactly node 0 fails over: {v}");
        assert_eq!(fo[0].get("addr").and_then(Value::as_str), Some(replica.addr.to_string().as_str()));
        assert_eq!(fo[0].get("proactive").and_then(Value::as_bool), Some(true));
        assert_eq!(fo[0].get("retries").and_then(Value::as_usize), Some(0), "proactive = no retry");
    }

    // the decisions are visible in the federated exposition (the
    // coordinator's own series now carry {role="coordinator"})
    let m = request(coord.addr, "{\"cmd\": \"metrics\"}");
    let text = m.get("metrics").and_then(Value::as_str).unwrap().to_string();
    let bh = bh_addr.to_string();
    let reroutes =
        federation::sample_value(&text, "lorif_coord_reroute_total", &[("role", "coordinator")])
            .expect("reroute counter present");
    assert!(reroutes >= N_QUERIES as f64, "every scatter leg rerouted: {reroutes}");
    assert_eq!(
        federation::sample_value(&text, "lorif_fleet_health_state", &[("node", &bh)]),
        Some(2.0),
        "black hole gauged down"
    );
    assert_eq!(
        federation::sample_value(&text, "lorif_fleet_up", &[("node", &bh)]),
        Some(0.0),
        "black hole never scraped"
    );

    // slowlog entries carry the per-node scatter stats of the pass
    let s = request(coord.addr, "{\"cmd\": \"slowlog\"}");
    let entries = s.get("slowlog").and_then(Value::as_arr).expect("slowlog array");
    assert_eq!(entries.len(), N_QUERIES);
    for e in entries {
        let nodes = e.get("nodes").and_then(Value::as_arr).expect("slowlog entry has nodes");
        assert_eq!(nodes.len(), n_nodes);
        assert!(
            nodes.iter().any(|n| n.get("proactive").and_then(Value::as_bool) == Some(true)),
            "the proactive leg is recorded: {e}"
        );
    }

    let summary = shutdown(coord);
    assert_eq!(summary.served, N_QUERIES);
    assert_eq!(summary.failed, 0);
    let s = shutdown(replica);
    assert_eq!(s.served, N_QUERIES, "the replica answered every query");
    for n in others {
        shutdown(n);
    }
    drop(bh_stop);

    // the JSONL event log: documented schema, monotone timestamps, and
    // the node_down + proactive-failover story
    let text = std::fs::read_to_string(&events).unwrap();
    let parsed: Vec<Value> =
        text.lines().map(|l| Value::parse(l).expect("event line parses")).collect();
    assert!(!parsed.is_empty());
    let mut prev = (0.0, -1.0);
    for e in &parsed {
        let ts = e.get("ts_ms").and_then(Value::as_f64).expect("ts_ms");
        let seq = e.get("seq").and_then(Value::as_f64).expect("seq");
        assert!(e.get("event").and_then(Value::as_str).is_some());
        assert!(e.get("node").and_then(Value::as_str).is_some());
        assert!(ts >= prev.0, "ts_ms must be monotone");
        assert!(seq > prev.1, "seq must strictly increase");
        prev = (ts, seq);
    }
    assert!(
        parsed.iter().any(|e| e.get("event").and_then(Value::as_str) == Some("node_down")
            && e.get("node").and_then(Value::as_str) == Some(bh.as_str())),
        "node_down logged for the black hole"
    );
    assert!(
        parsed.iter().any(|e| e.get("event").and_then(Value::as_str) == Some("failover")
            && e.get("node").and_then(Value::as_str) == Some(bh.as_str())
            && e.get("proactive").and_then(Value::as_bool) == Some(true)
            && e.get("replica").and_then(Value::as_str)
                == Some(replica.addr.to_string().as_str())),
        "proactive failover logged against the primary"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// One scrape of the coordinator shows the whole fleet: the federated
/// exposition carries every node's store ledger under its own `node`
/// label, the per-node sums reconcile with the local full-scan ledger,
/// and the coordinator's own series are labeled `{role="coordinator"}`.
#[test]
fn federated_metrics_carry_every_nodes_labeled_ledger() {
    let n_nodes = cluster_nodes();
    let shards = 2 * n_nodes;
    let stores = build_stores("fleet", shards, shards * 8);
    let (kernel, prune) = (Kernel::Lorif, PruneMode::Off);

    let nodes: Vec<Running> =
        (0..n_nodes).map(|i| start_node(kernel, &stores, vec![2 * i, 2 * i + 1], prune)).collect();
    let spec = nodes
        .iter()
        .enumerate()
        .map(|(i, n)| format!("{}={}-{}", n.addr, 2 * i, 2 * i + 1))
        .collect::<Vec<_>>()
        .join(",");
    let (coord, _fleet) = start_fleet_coordinator(
        &spec,
        0,
        FleetOptions {
            probe_interval: Duration::from_millis(100),
            probe_timeout: Duration::from_millis(250),
            scrape_interval: Duration::from_millis(100),
            fail_threshold: 3,
            event_log: None,
        },
    );

    let mut local = make_scorer(kernel, &stores, None, prune);
    let mut local_total = 0u64;
    for q in 0..N_QUERIES {
        let tokens = query_tokens(q);
        let (want, scan) = local_reference(&mut local, &tokens);
        local_total += scan;
        let v = request(coord.addr, &tokens_line(&tokens));
        assert_eq!(wire_bits(&v), want, "query {q}");
    }

    // poll until a scrape AFTER the last query landed: summed over the
    // fleet's labeled series, read + skipped equals the local full-scan
    // ledger (the registry counters preserve the same invariant the
    // per-reply ledgers do)
    let node_addrs: Vec<String> = nodes.iter().map(|n| n.addr.to_string()).collect();
    let deadline = Instant::now() + Duration::from_secs(10);
    let text = loop {
        let m = request(coord.addr, "{\"cmd\": \"metrics\"}");
        let text = m.get("metrics").and_then(Value::as_str).unwrap().to_string();
        let sum: f64 = node_addrs
            .iter()
            .map(|a| {
                let labels: &[(&str, &str)] = &[("node", a.as_str()), ("role", "node")];
                federation::sample_value(&text, "lorif_store_bytes_read_total", labels)
                    .unwrap_or(0.0)
                    + federation::sample_value(&text, "lorif_store_bytes_skipped_total", labels)
                        .unwrap_or(0.0)
            })
            .sum();
        if sum as u64 == local_total {
            break text;
        }
        assert!(
            Instant::now() < deadline,
            "federated ledger never reconciled: fleet sum {sum}, local {local_total}"
        );
        std::thread::sleep(Duration::from_millis(50));
    };

    // every node contributes its own distinctly-labeled series, every
    // endpoint scrapes up, and the coordinator's own counters are there
    // under {role="coordinator"}
    for a in &node_addrs {
        let labels: &[(&str, &str)] = &[("node", a.as_str()), ("role", "node")];
        assert!(
            federation::sample_value(&text, "lorif_store_bytes_read_total", labels).is_some(),
            "node {a} missing from the federated page"
        );
        assert_eq!(
            federation::sample_value(&text, "lorif_fleet_up", &[("node", a.as_str())]),
            Some(1.0),
            "node {a} not scraped up"
        );
    }
    let distinct: std::collections::BTreeSet<String> =
        federation::samples(&text, "lorif_store_bytes_read_total")
            .into_iter()
            .filter_map(|(ls, _)| ls.into_iter().find(|(k, _)| k == "node").map(|(_, v)| v))
            .collect();
    assert_eq!(distinct.len(), n_nodes, "one node label per member");
    assert_eq!(
        federation::sample_value(&text, "lorif_server_served_total", &[("role", "coordinator")]),
        Some(N_QUERIES as f64),
        "coordinator's own series labeled and current"
    );

    // the coordinator's slow-query log retained every (tiny) batch,
    // slowest-first, each with a trace ID and full per-node stats
    let s = request(coord.addr, "{\"cmd\": \"slowlog\"}");
    let entries = s.get("slowlog").and_then(Value::as_arr).expect("slowlog array");
    assert_eq!(entries.len(), N_QUERIES);
    let walls: Vec<f64> =
        entries.iter().map(|e| e.get("wall_s").and_then(Value::as_f64).unwrap()).collect();
    assert!(walls.windows(2).all(|w| w[0] >= w[1]), "slowlog sorted slowest-first: {walls:?}");
    for e in entries {
        assert!(e.get("trace_id").and_then(Value::as_usize).unwrap() >= 1);
        assert!(e.get("latency").and_then(|l| l.get("bytes_read")).is_some());
        assert_eq!(e.get("nodes").and_then(Value::as_arr).map(|n| n.len()), Some(n_nodes));
    }

    let summary = shutdown(coord);
    assert_eq!(summary.served, N_QUERIES);
    assert_eq!(summary.failed, 0);
    for n in nodes {
        let s = shutdown(n);
        assert_eq!(s.served, N_QUERIES);
    }
}

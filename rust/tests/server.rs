//! Concurrent-serving integration tests: the acceptor -> batcher ->
//! scoring-worker pipeline under simultaneous clients, poisoned
//! batches, malformed requests, overload bursts, and shutdown races.
//!
//! The invariants every scenario asserts:
//!   * EVERY request gets exactly one reply — scores, a structured
//!     error (`invalid_tokens` / `batch_failed` / `shutdown`), or an
//!     `overloaded` shed; nobody hangs and nobody's error kills the
//!     service for anyone else.
//!   * `run` returns after a shutdown command and the listening port is
//!     RELEASED (regression: the old server leaked the acceptor thread
//!     blocked in `accept`, keeping the address bound).
//!
//! `LORIF_SERVER_CLIENTS` raises the concurrent-client count (the CI
//! nightly hardening job runs a larger burst than the per-PR default).
//!
//! The gradient source is a deterministic CPU fake (the `GradSource`
//! seam the XLA extractor also plugs into), so the whole pipeline runs
//! without the `xla` feature; scoring is real — GradDot over a real
//! on-disk store, streamed through the shared executor with a shared
//! decoded-chunk cache.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use lorif::attribution::{QueryGrads, QueryLayer, Scorer};
use lorif::linalg::Mat;
use lorif::query::server::{GradSource, ServeSummary, Server, ServerConfig};
use lorif::runtime::{ExtractBatch, LayerGrads};
use lorif::store::{ChunkCache, ShardSet, StoreKind, StoreMeta, StoreWriter};
use lorif::util::json::Value;
use lorif::util::prng::Rng;

const VOCAB: usize = 64;
const SEQ_LEN: usize = 8;
const DIMS: [(usize, usize); 2] = [(2, 3), (2, 2)];
/// a VALID token id the fake source refuses to extract (poisons its batch)
const POISON: i32 = 13;

fn stress_clients() -> usize {
    std::env::var("LORIF_SERVER_CLIENTS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(6)
}

/// Deterministic CPU gradient source; `delay` simulates extraction cost
/// so batches overlap, `POISON` anywhere in the batch fails extraction.
struct FakeSource {
    delay: Duration,
}

impl GradSource for FakeSource {
    fn vocab(&self) -> usize {
        VOCAB
    }

    fn seq_len(&self) -> usize {
        SEQ_LEN
    }

    fn extract(&mut self, tokens: &[i32], n: usize) -> anyhow::Result<QueryGrads> {
        assert_eq!(tokens.len(), n * SEQ_LEN, "batcher must hand fixed-length rows");
        if tokens.contains(&POISON) {
            anyhow::bail!("poisoned batch (token {POISON})");
        }
        std::thread::sleep(self.delay);
        let layers = DIMS
            .iter()
            .map(|&(d1, d2)| {
                let mut g = Mat::zeros(n, d1 * d2);
                for q in 0..n {
                    let row = &tokens[q * SEQ_LEN..(q + 1) * SEQ_LEN];
                    for (j, x) in g.row_mut(q).iter_mut().enumerate() {
                        *x = row[j % SEQ_LEN] as f32 + 0.125 * j as f32;
                    }
                }
                QueryLayer { g, u: Mat::zeros(n, d1), v: Mat::zeros(n, d2) }
            })
            .collect();
        Ok(QueryGrads { n_query: n, c: 1, proj_dims: DIMS.to_vec(), layers })
    }
}

fn write_test_store(name: &str, n: usize) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("lorif_server_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join(name);
    let meta = StoreMeta {
        kind: StoreKind::Dense,
        tier: "small".into(),
        f: 4,
        c: 1,
        layers: DIMS.to_vec(),
        n_examples: 0,
        shards: None,
        summary_chunk: None,
        codec: lorif::store::CodecId::Bf16,
    };
    let mut rng = Rng::new(7);
    let layers: Vec<LayerGrads> = DIMS
        .iter()
        .map(|&(d1, d2)| LayerGrads {
            g: Mat::random_normal(n, d1 * d2, 1.0, &mut rng),
            u: Mat::zeros(n, d1),
            v: Mat::zeros(n, d2),
        })
        .collect();
    let mut w = StoreWriter::create(&base, meta).unwrap();
    w.append(&ExtractBatch { losses: vec![0.0; n], layers, valid: n }).unwrap();
    w.finalize().unwrap();
    base
}

/// A pool of GradDot workers sharing ONE store + decoded-chunk cache.
fn scorer_pool(base: &std::path::Path, workers: usize) -> Vec<Box<dyn Scorer + Send>> {
    let mut set = ShardSet::open(base).unwrap();
    set.set_cache(Some(ChunkCache::with_capacity(8 << 20)));
    let set = Arc::new(set);
    (0..workers)
        .map(|_| {
            let mut s = lorif::attribution::graddot::GradDotScorer::new(Arc::clone(&set));
            s.chunk_size = 16;
            s.score_threads = 1;
            Box::new(s) as Box<dyn Scorer + Send>
        })
        .collect()
}

struct Running {
    addr: SocketAddr,
    handle: std::thread::JoinHandle<anyhow::Result<ServeSummary>>,
}

fn start_server(name: &str, cfg_mut: impl FnOnce(&mut ServerConfig), delay_ms: u64) -> Running {
    let base = write_test_store(name, 40);
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        window_ms: 5,
        topk: 3,
        queue_cap: 32,
        io_timeout_ms: 0,
        shards_served: 0,
        slowlog_cap: 32,
    };
    cfg_mut(&mut cfg);
    let scorers = scorer_pool(&base, 2);
    let server = Server::bind(cfg).unwrap();
    let addr = server.local_addr();
    let source = FakeSource { delay: Duration::from_millis(delay_ms) };
    let handle = std::thread::spawn(move || server.run(source, scorers));
    Running { addr, handle }
}

/// One request, one reply line, parsed.
fn request(addr: SocketAddr, line: &str) -> Value {
    let mut s = TcpStream::connect(addr).expect("connect");
    writeln!(s, "{line}").unwrap();
    let mut r = BufReader::new(s);
    let mut resp = String::new();
    r.read_line(&mut resp).expect("read reply");
    assert!(!resp.trim().is_empty(), "server must always reply (got EOF)");
    Value::parse(resp.trim()).expect("reply is JSON")
}

fn shutdown(addr: SocketAddr) {
    let v = request(addr, "{\"cmd\": \"shutdown\"}");
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true));
}

fn finish(r: Running) -> ServeSummary {
    shutdown(r.addr);
    let summary = r.handle.join().expect("server thread").expect("serve result");
    // the port must be RELEASED once run() returns (regression: leaked
    // acceptor kept it bound)
    let rebind = TcpListener::bind(r.addr);
    assert!(rebind.is_ok(), "port still bound after shutdown: {rebind:?}");
    summary
}

fn code_of(v: &Value) -> Option<&str> {
    v.get("code").and_then(Value::as_str)
}

#[test]
fn concurrent_clients_mixed_valid_invalid_all_answered() {
    // queue >= the stress client count so no VALID request is shed even
    // in the hardening job's larger burst
    let r = start_server("concurrent_mixed", |c| c.queue_cap = stress_clients().max(64), 2);
    let addr = r.addr;
    let clients = stress_clients();
    let per_client = 4usize;
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut valid = 0usize;
                let mut invalid = 0usize;
                for i in 0..per_client {
                    // interleave valid requests with each malformed kind
                    let (line, expect_valid): (String, bool) = match (c + i) % 4 {
                        0 => (format!("{{\"tokens\": [{}, {}]}}", c % 12, i % 8), true),
                        1 => ("{\"tokens\": [1, \"x\", 3]}".into(), false),
                        2 => ("{\"tokens\": [1, 9999]}".into(), false),
                        _ => {
                            // over-length: seq_len + 1 ids
                            let toks: Vec<String> =
                                (0..SEQ_LEN + 1).map(|t| (t % 8).to_string()).collect();
                            (format!("{{\"tokens\": [{}]}}", toks.join(", ")), false)
                        }
                    };
                    let v = request(addr, &line);
                    if expect_valid {
                        assert!(v.get("topk").is_some(), "valid request got {v}");
                        assert!(v.get("cache_hits").is_some(), "reply carries cache stats");
                        valid += 1;
                    } else {
                        assert_eq!(code_of(&v), Some("invalid_tokens"), "got {v}");
                        assert!(
                            v.get("index").and_then(Value::as_usize).is_some(),
                            "invalid-token error must name the offending index: {v}"
                        );
                        invalid += 1;
                    }
                }
                (valid, invalid)
            })
        })
        .collect();
    let mut total_valid = 0usize;
    for h in handles {
        let (v, i) = h.join().unwrap();
        total_valid += v;
        assert_eq!(v + i, per_client, "every request answered");
    }
    let summary = finish(r);
    assert_eq!(summary.served, total_valid, "every valid request scored");
    assert_eq!(summary.failed, 0);
}

#[test]
fn poisoned_batch_answers_its_clients_and_serving_continues() {
    // max_batch 1 + window 0 isolates each request in its own batch
    let r = start_server(
        "poison",
        |c| {
            c.max_batch = 1;
            c.window_ms = 0;
        },
        0,
    );
    let addr = r.addr;
    let ok = request(addr, "{\"tokens\": [1, 2, 3]}");
    assert!(ok.get("topk").is_some(), "{ok}");

    // POISON is a VALID token id, so it passes validation and fails in
    // gradient extraction — the batch's clients get a structured error...
    let bad = request(addr, &format!("{{\"tokens\": [{POISON}]}}"));
    assert_eq!(code_of(&bad), Some("batch_failed"), "{bad}");
    assert!(
        bad.get("error").and_then(Value::as_str).unwrap().contains("poisoned"),
        "{bad}"
    );

    // ...and the server keeps serving (regression: `?` in the batch
    // loop used to tear the whole service down)
    let again = request(addr, "{\"tokens\": [4, 5]}");
    assert!(again.get("topk").is_some(), "server died after a bad batch: {again}");

    let summary = finish(r);
    assert_eq!(summary.served, 2);
    assert_eq!(summary.failed, 1);
}

#[test]
fn overload_burst_sheds_with_structured_error_and_answers_everyone() {
    let r = start_server(
        "overload",
        |c| {
            c.max_batch = 1;
            c.window_ms = 0;
            c.queue_cap = 1;
        },
        40, // slow extraction: the queue backs up immediately
    );
    let addr = r.addr;
    let clients = stress_clients().max(10);
    let barrier = Arc::new(std::sync::Barrier::new(clients));
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait(); // fire simultaneously
                let v = request(addr, &format!("{{\"tokens\": [{}]}}", c % 8));
                if v.get("topk").is_some() {
                    (1usize, 0usize)
                } else {
                    assert_eq!(code_of(&v), Some("overloaded"), "unexpected reply {v}");
                    assert!(v.get("queue_depth").is_some(), "{v}");
                    (0, 1)
                }
            })
        })
        .collect();
    let (mut served, mut shed) = (0usize, 0usize);
    for h in handles {
        let (s, d) = h.join().unwrap();
        served += s;
        shed += d;
    }
    assert_eq!(served + shed, clients, "every client answered exactly once");
    assert!(served >= 1, "at least the first request is served");
    assert!(shed >= 1, "a {clients}-client burst into a 1-slot queue must shed");
    let summary = finish(r);
    assert_eq!(summary.served, served);
    assert_eq!(summary.shed, shed);
}

#[test]
fn shutdown_mid_batch_still_answers_the_pending_client() {
    // long window: the first query's batch is still open when shutdown
    // arrives on another connection
    let r = start_server(
        "mid_batch",
        |c| {
            c.max_batch = 8;
            c.window_ms = 300;
        },
        0,
    );
    let addr = r.addr;
    let client = std::thread::spawn(move || request(addr, "{\"tokens\": [3, 1]}"));
    std::thread::sleep(Duration::from_millis(50)); // let the batch open
    let summary = finish(r);
    let v = client.join().unwrap();
    // the in-flight batch is flushed on shutdown: the client gets real
    // scores (or, in a tight race, a structured shutdown error — never
    // a hang, never a bare EOF)
    assert!(
        v.get("topk").is_some() || code_of(&v) == Some("shutdown"),
        "pending client got {v}"
    );
    if v.get("topk").is_some() {
        assert_eq!(summary.served, 1);
    }
}

#[test]
fn stats_endpoint_reports_counters_and_cache_hit_rate() {
    let r = start_server(
        "stats",
        |c| {
            c.max_batch = 1;
            c.window_ms = 0;
        },
        0,
    );
    let addr = r.addr;
    // two identical queries: the second batch's store pass hits the
    // shared decoded-chunk cache
    for _ in 0..2 {
        let v = request(addr, "{\"tokens\": [2, 4, 6]}");
        assert!(v.get("topk").is_some(), "{v}");
    }
    let stats = request(addr, "{\"cmd\": \"stats\"}");
    assert_eq!(stats.get("served").and_then(Value::as_usize), Some(2));
    assert_eq!(stats.get("shed").and_then(Value::as_usize), Some(0));
    assert_eq!(stats.get("workers").and_then(Value::as_usize), Some(2));
    assert!(stats.get("queue_depth").and_then(Value::as_usize).is_some());
    let hits = stats.get("cache_hits").and_then(Value::as_usize).unwrap();
    let misses = stats.get("cache_misses").and_then(Value::as_usize).unwrap();
    assert!(misses >= 1, "first pass decodes from disk: {stats}");
    assert!(hits >= 1, "second pass must hit the shared chunk cache: {stats}");
    let rate = stats.get("cache_hit_rate").and_then(Value::as_f64).unwrap();
    assert!(rate > 0.0 && rate < 1.0, "hit rate {rate}");

    // unknown commands and garbage lines get structured errors too
    let v = request(addr, "{\"cmd\": \"selfdestruct\"}");
    assert_eq!(code_of(&v), Some("bad_request"));
    let v = request(addr, "this is not json");
    assert_eq!(code_of(&v), Some("bad_json"));
    finish(r);
}

#[test]
fn stalled_connection_times_out_with_structured_error() {
    let r = start_server("io_timeout", |c| c.io_timeout_ms = 150, 0);
    let addr = r.addr;
    // a client that stalls mid-line: without --io-timeout-ms it would
    // pin its handler thread forever; with it, the read times out and
    // the server answers with a structured timeout error, then closes
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "{{\"tokens\": [1,").unwrap(); // no newline: the line never completes
    s.flush().unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let mut resp = String::new();
    reader.read_line(&mut resp).expect("timeout error reply");
    let v = Value::parse(resp.trim()).expect("reply is JSON");
    assert_eq!(code_of(&v), Some("timeout"), "{v}");
    // the service stays healthy for well-behaved clients
    let v = request(addr, "{\"tokens\": [1, 2]}");
    assert!(v.get("topk").is_some(), "{v}");
    finish(r);
}

/// One sample value from a Prometheus text exposition (plain counter /
/// gauge lines, not `_bucket` series).
fn metric_value(text: &str, name: &str) -> u64 {
    let prefix = format!("{name} ");
    let line = text
        .lines()
        .find(|l| l.starts_with(&prefix))
        .unwrap_or_else(|| panic!("exposition missing sample for {name}"));
    line[prefix.len()..].trim().parse::<f64>().expect("numeric sample") as u64
}

#[test]
fn metrics_exposition_reconciles_under_concurrent_load() {
    // a small queue + slow extraction so a concurrent burst takes every
    // path: served, and usually some shed; the registry must reconcile
    // exactly whatever mix happens
    let r = start_server(
        "metrics_load",
        |c| {
            c.max_batch = 2;
            c.window_ms = 2;
            c.queue_cap = 2;
        },
        10,
    );
    let addr = r.addr;
    let clients = stress_clients().max(8);
    let barrier = Arc::new(std::sync::Barrier::new(clients));
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let v = request(addr, &format!("{{\"tokens\": [{}, {}]}}", c % 8, (c + 1) % 8));
                assert!(
                    v.get("topk").is_some() || code_of(&v) == Some("overloaded"),
                    "unexpected reply {v}"
                );
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // scrape the Prometheus exposition over the wire: it rides the
    // line protocol as one JSON string
    let m = request(addr, "{\"cmd\": \"metrics\"}");
    assert_eq!(m.get("ok").and_then(Value::as_bool), Some(true), "{m}");
    let text = m.get("metrics").and_then(Value::as_str).unwrap().to_string();
    assert!(
        text.contains("# TYPE lorif_server_submitted_total counter"),
        "exposition lost its TYPE lines"
    );

    // every submitted query landed in exactly one outcome bucket —
    // asserted through the exposition, not the internal structs
    let submitted = metric_value(&text, "lorif_server_submitted_total");
    let served = metric_value(&text, "lorif_server_served_total");
    let shed = metric_value(&text, "lorif_server_shed_total");
    let failed = metric_value(&text, "lorif_server_failed_total");
    let dropped = metric_value(&text, "lorif_server_dropped_total");
    assert_eq!(submitted, clients as u64, "each client submitted exactly one query");
    assert_eq!(
        served + shed + failed + dropped,
        submitted,
        "outcome counters must reconcile: {served} + {shed} + {failed} + {dropped} != {submitted}"
    );
    assert_eq!(metric_value(&text, "lorif_server_queue_depth"), 0, "queue drained");
    assert!(served >= 1, "at least one query scored");
    // the scoring passes published the store families into THIS
    // server's registry (the with_ctx scoping the workers run under)
    assert!(metric_value(&text, "lorif_store_bytes_read_total") > 0, "store pass published");
    assert!(metric_value(&text, "lorif_server_batch_wall_seconds_count") >= 1);

    // the stats verb derives from the same registry — the two views
    // cannot disagree
    let stats = request(addr, "{\"cmd\": \"stats\"}");
    assert_eq!(stats.get("submitted").and_then(Value::as_usize), Some(submitted as usize));
    assert_eq!(stats.get("served").and_then(Value::as_usize), Some(served as usize));
    assert!(stats.get("uptime_s").and_then(Value::as_f64).unwrap() > 0.0);
    let p95 = stats.get("batch_wall_p95_s").and_then(Value::as_f64).unwrap();
    assert!(p95 > 0.0, "batch wall percentiles populated: {stats}");

    let summary = finish(r);
    assert_eq!(summary.served as u64, served, "summary is the registry's view");
    assert_eq!(summary.shed as u64, shed);
}

#[test]
fn health_verb_reports_liveness_fields() {
    let r = start_server(
        "health",
        |c| {
            c.max_batch = 1;
            c.window_ms = 0;
        },
        0,
    );
    let addr = r.addr;
    // probe before any query: health must be observable on a fresh server
    let h = request(addr, "{\"cmd\": \"health\"}");
    assert_eq!(h.get("ok").and_then(Value::as_bool), Some(true), "{h}");
    assert_eq!(h.get("served").and_then(Value::as_usize), Some(0));
    assert_eq!(h.get("workers").and_then(Value::as_usize), Some(2));
    assert_eq!(h.get("queue_depth").and_then(Value::as_usize), Some(0));
    assert!(h.get("uptime_s").and_then(Value::as_f64).unwrap() >= 0.0);
    assert!(h.get("shards").and_then(Value::as_usize).is_some());
    // ...and it tracks the served counter
    let v = request(addr, "{\"tokens\": [2, 3]}");
    assert!(v.get("topk").is_some(), "{v}");
    let h = request(addr, "{\"cmd\": \"health\"}");
    assert_eq!(h.get("served").and_then(Value::as_usize), Some(1), "{h}");
    finish(r);
}

#[test]
fn slowlog_verb_returns_slowest_batches_with_breakdowns() {
    let r = start_server(
        "slowlog",
        |c| {
            c.max_batch = 1;
            c.window_ms = 0;
            c.slowlog_cap = 2;
        },
        5,
    );
    let addr = r.addr;
    // empty before any batch
    let v = request(addr, "{\"cmd\": \"slowlog\"}");
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "{v}");
    assert!(v.get("slowlog").and_then(Value::as_arr).unwrap().is_empty());
    // three single-query batches into a cap-2 ring: the ring keeps the
    // two slowest (whichever they are — asserting the SHAPE and the
    // slowest-first ordering, which is deterministic)
    for t in 0..3 {
        let v = request(addr, &format!("{{\"tokens\": [{t}, 2]}}"));
        assert!(v.get("topk").is_some(), "{v}");
    }
    let v = request(addr, "{\"cmd\": \"slowlog\"}");
    let entries = v.get("slowlog").and_then(Value::as_arr).unwrap();
    assert_eq!(entries.len(), 2, "cap-2 ring holds exactly 2 of the 3 batches: {v}");
    let walls: Vec<f64> =
        entries.iter().map(|e| e.get("wall_s").and_then(Value::as_f64).unwrap()).collect();
    assert!(walls[0] >= walls[1], "slowest-first ordering: {walls:?}");
    for e in entries {
        assert_eq!(e.get("batch").and_then(Value::as_usize), Some(1), "{e}");
        assert!(e.get("trace_id").and_then(Value::as_usize).is_some(), "{e}");
        assert!(e.get("ts_s").and_then(Value::as_f64).unwrap() >= 0.0, "{e}");
        let lat = e.get("latency").expect("latency breakdown");
        assert!(lat.get("bytes_read").and_then(Value::as_usize).unwrap() > 0, "{e}");
        assert!(lat.get("compute_s").and_then(Value::as_f64).is_some(), "{e}");
        // local plane: no nodes array
        assert!(e.get("nodes").is_none(), "{e}");
    }
    // the registry tracked admissions and occupancy
    let m = request(addr, "{\"cmd\": \"metrics\"}");
    let text = m.get("metrics").and_then(Value::as_str).unwrap().to_string();
    assert!(metric_value(&text, "lorif_slowlog_admitted_total") >= 2);
    assert_eq!(metric_value(&text, "lorif_slowlog_entries"), 2);
    finish(r);
}

#[test]
fn caller_trace_id_is_adopted_and_malformed_trace_is_ignored() {
    let r = start_server(
        "trace_field",
        |c| {
            c.max_batch = 1;
            c.window_ms = 0;
            c.slowlog_cap = 8;
        },
        0,
    );
    let addr = r.addr;
    // a forwarded trace ID must label the batch's slowlog entry — the
    // handle that joins a coordinator's trace file with the node's
    let v = request(addr, "{\"tokens\": [1, 2], \"trace\": 777}");
    assert!(v.get("topk").is_some(), "{v}");
    // malformed trace values are ignored, never rejected
    for bad in ["\"x\"", "-3", "1.5", "0"] {
        let v = request(addr, &format!("{{\"tokens\": [3], \"trace\": {bad}}}"));
        assert!(v.get("topk").is_some(), "trace {bad} must not reject the query: {v}");
    }
    let v = request(addr, "{\"cmd\": \"slowlog\"}");
    let entries = v.get("slowlog").and_then(Value::as_arr).unwrap();
    assert_eq!(entries.len(), 5, "{v}");
    let with_777 = entries
        .iter()
        .filter(|e| e.get("trace_id").and_then(Value::as_usize) == Some(777))
        .count();
    assert_eq!(with_777, 1, "exactly the forwarded ID is adopted: {v}");
    finish(r);
}

#[test]
fn cached_and_cold_replies_are_bit_identical() {
    // same request against a cache-backed pool and a cold pool: the
    // top-k indices and scores in the reply must match exactly
    let base = write_test_store("bitident", 40);
    let run_once = |with_cache: bool, name: &str| -> (Vec<usize>, Vec<f64>) {
        let mut set = ShardSet::open(&base).unwrap();
        if with_cache {
            set.set_cache(Some(ChunkCache::with_capacity(8 << 20)));
        }
        let set = Arc::new(set);
        let scorers: Vec<Box<dyn Scorer + Send>> = (0..2)
            .map(|_| {
                let mut s =
                    lorif::attribution::graddot::GradDotScorer::new(Arc::clone(&set));
                s.chunk_size = 16;
                s.score_threads = 1;
                Box::new(s) as Box<dyn Scorer + Send>
            })
            .collect();
        let server = Server::bind(ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_batch: 1,
            window_ms: 0,
            topk: 5,
            queue_cap: 8,
            io_timeout_ms: 0,
            shards_served: 0,
            slowlog_cap: 32,
        })
        .unwrap();
        let addr = server.local_addr();
        let handle = std::thread::spawn(move || {
            server.run(FakeSource { delay: Duration::ZERO }, scorers)
        });
        // twice with a cache: the second reply is served FROM the cache
        let mut last = None;
        for _ in 0..2 {
            last = Some(request(addr, "{\"tokens\": [5, 2, 7, 1]}"));
        }
        let v = last.unwrap();
        assert!(v.get("topk").is_some(), "{name}: {v}");
        let topk: Vec<usize> = v
            .get("topk")
            .and_then(Value::as_arr)
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        let scores: Vec<f64> = v
            .get("scores")
            .and_then(Value::as_arr)
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        if with_cache {
            assert!(
                v.get("cache_hits").and_then(Value::as_usize).unwrap() >= 1,
                "{name}: warm reply must be cache-served: {v}"
            );
        }
        let v = request(addr, "{\"cmd\": \"shutdown\"}");
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true));
        handle.join().unwrap().unwrap();
        (topk, scores)
    };
    let (cold_topk, cold_scores) = run_once(false, "cold");
    let (warm_topk, warm_scores) = run_once(true, "cached");
    assert_eq!(warm_topk, cold_topk, "cache changed the top-k");
    assert_eq!(warm_scores, cold_scores, "cache changed the scores");
}

//! Integration tests over the real AOT artifacts: runtime loading,
//! gradient extraction vs the CPU oracle, full index build, and
//! cross-method scoring on a small live pipeline.
//!
//! Requires the `xla` cargo feature (compiled out otherwise) and
//! `make artifacts` (skipped with a clear message otherwise).

#![cfg(feature = "xla")]

use lorif::app::{build_store_scorer, Method};
use lorif::attribution::{QueryGrads, Scorer};
use lorif::config::Config;
use lorif::index::{Pipeline, Stage1Options};
use lorif::model::spec::Tier;
use lorif::query::QueryEngine;
use lorif::runtime::{GradExtractor, LossEval, Runtime, Trainer};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn test_config(name: &str) -> Config {
    let mut cfg = Config::default();
    cfg.n_train = 128;
    cfg.n_query = 8;
    cfg.train_steps = 40;
    cfg.r = 24;
    cfg.work_dir = std::env::temp_dir().join("lorif_itest").join(name);
    cfg
}

#[test]
fn manifest_loads_and_validates() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    assert!(rt.manifest.graphs.len() >= 20);
    assert!(rt.manifest.graph("grad_extract_small_f4_c1").is_ok());
    assert!(rt.manifest.graph("nonexistent").is_err());
}

#[test]
fn extraction_matches_cpu_factorization_oracle() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let tier = Tier::Small;
    let spec = tier.spec();
    let params = spec.init_params(3);
    let lit = lorif::runtime::lit_f32(&params, &[params.len() as i64]).unwrap();
    let tm = lorif::corpus::TopicModel::new(4, 9);
    let data = lorif::corpus::Dataset::generate(&tm, 8, 64, 10);
    let ex = GradExtractor::new(&rt, tier, 4, 1).unwrap();
    let batch = ex.run(&rt, &lit, &data, &(0..8).collect::<Vec<_>>()).unwrap();
    assert_eq!(batch.losses.len(), 8);
    assert!(batch.losses.iter().all(|&l| l > 2.0 && l < 8.0));
    // the kernel's u,v must match the CPU power-iteration oracle run on
    // the kernel's own G
    for (l, lg) in batch.layers.iter().enumerate() {
        let (d1, d2) = ex.proj_dims[l];
        for e in [0usize, 3, 7] {
            let g = lorif::linalg::Mat::from_vec(d1, d2, lg.g.row(e).to_vec());
            assert!(g.frob_norm() > 0.0, "zero gradient at layer {l}");
            let (u_cpu, v_cpu) = lorif::grads::factorize::poweriter(&g, 1, 8);
            let rec_cpu = u_cpu.matmul_nt(&v_cpu);
            let u = lorif::linalg::Mat::from_vec(d1, 1, lg.u.row(e).to_vec());
            let v = lorif::linalg::Mat::from_vec(d2, 1, lg.v.row(e).to_vec());
            let rec_kernel = u.matmul_nt(&v);
            // compare reconstruction errors (direction-stable invariant)
            let err = |r: &lorif::linalg::Mat| {
                let mut e2 = 0.0f32;
                for (x, y) in r.data.iter().zip(&g.data) {
                    e2 += (x - y) * (x - y);
                }
                e2.sqrt() / g.frob_norm()
            };
            let (ek, ec) = (err(&rec_kernel), err(&rec_cpu));
            assert!((ek - ec).abs() < 0.05, "layer {l} ex {e}: kernel {ek} vs cpu {ec}");
        }
    }
}

#[test]
fn training_reduces_loss_and_is_deterministic() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let tier = Tier::Small;
    let tm = lorif::corpus::TopicModel::new(4, 2);
    let data = lorif::corpus::Dataset::generate(&tm, 64, 64, 3);
    let run = || {
        let mut trainer = Trainer::new(&rt, tier, tier.spec().init_params(5)).unwrap();
        let mut rng = lorif::util::prng::Rng::new(6);
        trainer.train(&rt, &data, 30, 3e-3, &mut rng).unwrap()
    };
    let l1 = run();
    let l2 = run();
    assert_eq!(l1, l2, "training must be deterministic");
    assert!(l1.last().unwrap() < &(l1[0] - 0.5), "{:?}", &l1[..3]);
}

#[test]
fn full_pipeline_lorif_vs_logra_agree_on_top_proponents() {
    let _dir = require_artifacts!();
    let cfg = test_config("pipeline");
    let p = Pipeline::new(cfg).unwrap();
    let (train, queries) = p.corpus().unwrap();
    let params = p.base_params(&train).unwrap();
    let lit = p.params_literal(&params).unwrap();
    p.stage1(&lit, &train, Stage1Options::default()).unwrap();

    let qg = p.query_grads(&lit, &queries).unwrap();
    let lorif = build_store_scorer(&p, Method::Lorif).unwrap();
    let logra = build_store_scorer(&p, Method::Logra).unwrap();
    let r1 = QueryEngine::new(lorif, 10).run(&qg).unwrap();
    let r2 = QueryEngine::new(logra, 10).run(&qg).unwrap();

    // per-query score correlation between LoRIF (approx) and LoGRA
    // (dense): must be clearly positive
    let s1 = r1.scores.as_ref().expect("full sink");
    let s2 = r2.scores.as_ref().expect("full sink");
    let mut mean_rho = 0.0;
    for q in 0..queries.len() {
        let rho = lorif::eval::spearman::spearman(s1.row(q), s2.row(q));
        mean_rho += rho / queries.len() as f64;
    }
    assert!(mean_rho > 0.35, "lorif-logra rank correlation too low: {mean_rho}");
    // the factored index must be much smaller
    assert!(r1.latency.bytes_read * 4 < r2.latency.bytes_read);
}

#[test]
fn graddot_equals_lorif_with_zero_curvature() {
    let _dir = require_artifacts!();
    let cfg = test_config("graddot_limit");
    let p = Pipeline::new(cfg).unwrap();
    let (train, queries) = p.corpus().unwrap();
    let params = p.base_params(&train).unwrap();
    let lit = p.params_literal(&params).unwrap();
    p.stage1(&lit, &train, Stage1Options::default()).unwrap();
    let qg = p.query_grads(&lit, &queries).unwrap();

    // graddot on the dense store
    let graddot = build_store_scorer(&p, Method::GradDot).unwrap();
    let rd = QueryEngine::new(graddot, 5).run(&qg).unwrap();

    // lorif with weights zeroed (r -> 0 limit) and lambda = 1
    let (curv, _) = p.stage2_lorif().unwrap();
    let mut curv = curv;
    for w in &mut curv.weights {
        w.iter_mut().for_each(|x| *x = 0.0);
    }
    for l in &mut curv.lambdas {
        *l = 1.0;
    }
    let shards = lorif::store::ShardSet::open(&p.factored_base()).unwrap();
    let mut scorer = lorif::attribution::LorifScorer::new(shards, curv);
    scorer.prefetch = false;
    let rl = scorer.score(&qg).unwrap();

    // rank-1 factor dots approximate the dense dots: positive rank corr
    let sd = rd.scores.as_ref().expect("full sink");
    let mut mean_rho = 0.0;
    for q in 0..queries.len() {
        mean_rho += lorif::eval::spearman::spearman(rl.scores().row(q), sd.row(q))
            / queries.len() as f64;
    }
    assert!(mean_rho > 0.3, "zero-curvature lorif vs graddot: {mean_rho}");
}

#[test]
fn loss_eval_consistent_with_training_loss() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let tier = Tier::Small;
    let tm = lorif::corpus::TopicModel::new(4, 2);
    let data = lorif::corpus::Dataset::generate(&tm, 32, 64, 3);
    let params = tier.spec().init_params(1);
    let lit = lorif::runtime::lit_f32(&params, &[params.len() as i64]).unwrap();
    let le = LossEval::new(&rt, tier).unwrap();
    let losses = le.losses(&rt, &lit, &data).unwrap();
    assert_eq!(losses.len(), 32);
    // untrained model on 64-token vocab: loss near ln(64)=4.16
    let mean: f32 = losses.iter().sum::<f32>() / 32.0;
    assert!((mean - 4.16).abs() < 0.5, "{mean}");
}

#[test]
fn tail_patch_improves_query_probability_for_true_proponents() {
    let _dir = require_artifacts!();
    let mut cfg = test_config("tailpatch");
    cfg.train_steps = 80;
    let p = Pipeline::new(cfg).unwrap();
    let (train, queries) = p.corpus().unwrap();
    let params = p.base_params(&train).unwrap();
    // oracle proponents: same-topic training examples
    let topk: Vec<Vec<usize>> = (0..queries.len())
        .map(|q| {
            (0..train.len())
                .filter(|&t| train.topics[t] == queries.topics[q])
                .take(8)
                .collect()
        })
        .collect();
    let scores = lorif::eval::tail_patch(
        &p,
        &params,
        &train,
        &queries,
        &topk,
        lorif::eval::TailPatchProtocol { k: 8, lr: 1e-2 },
    )
    .unwrap();
    let mean: f64 = scores.iter().sum::<f64>() / scores.len() as f64;
    assert!(mean > 0.0, "oracle tail-patch should be positive: {mean}");
}

//! Type-level stub of the PJRT/XLA bindings the `xla` cargo feature
//! expects.  It satisfies the compile-time surface (`Literal`,
//! `PjRtClient`, executables, HLO protos) so `cargo build --features
//! xla` type-checks in environments without the real rust_pallas
//! toolchain; every runtime entry point returns a clear error.
//!
//! To actually execute AOT artifacts, replace this path dependency with
//! the real `xla` crate (see rust/README.md).

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} is unavailable (link the real rust_pallas `xla` crate)"
    )))
}

/// Scalar element types a literal can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> &'static str {
        "xla-stub"
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

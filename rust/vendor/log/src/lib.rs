//! Offline stand-in for the `log` facade crate, covering the subset this
//! repository uses: the five level macros, `Level`/`LevelFilter`, the
//! `Log` trait, and `set_logger`/`set_max_level`.

use std::cmp;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        false
    }
    fn log(&self, _record: &Record) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger has already been set")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize <= MAX_LEVEL.load(Ordering::Relaxed) {
        let metadata = Metadata { level, target };
        let logger = logger();
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filter_comparisons() {
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Trace >= Level::Trace);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}

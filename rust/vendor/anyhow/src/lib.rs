//! Offline stand-in for the `anyhow` crate, API-compatible with the
//! subset this repository uses: `anyhow::Result`, the `anyhow!`,
//! `bail!`, and `ensure!` macros, and `?`-conversion from any
//! `std::error::Error` type.
//!
//! The error is a formatted message (no backtraces, no downcasting) —
//! enough for a CLI/test codebase whose errors are read by humans.

use std::fmt;

/// A message-carrying error type.
///
/// Deliberately does NOT implement `std::error::Error`: the blanket
/// `From<E: std::error::Error>` conversion below would otherwise
/// overlap with the reflexive `From<Error> for Error` impl in std.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    pub fn new<E: std::error::Error>(error: E) -> Error {
        Error { msg: error.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("nope").is_err());
    }
}
